"""Compiled lane programs: segment partitioning, jit/python fusion modes,
bitwise equivalence vs the per-op interpreter oracle across all three
plan kinds, program caching, and error propagation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EdgeSoCCostModel, FusedOp, OpGraph, Orchestrator,
                        Plan, ScheduleExecutor, chain_graph,
                        results_bitwise_equal)
from repro.core.costmodel import EDGE_PUS
from repro.core.laneprogram import JIT, PYTHON
from repro.core.schedule import ConcurrentSchedule, ConcurrentStep


@pytest.fixture(scope="module")
def model():
    return EdgeSoCCostModel()


def _x(dim=8, lo=0.0, hi=1.0):
    return jnp.linspace(lo, hi, dim * dim, dtype=jnp.float32).reshape(dim, dim)


def _jax_chain(n=8, salt=0.0, dim=8):
    """Chain of jittable jnp payloads (tanh-terminated: no FMA-contraction
    hazard, so segments must jit and stay bitwise)."""
    ops = []
    for i in range(n):
        c = jnp.float32(1.0 + 0.01 * i + salt)
        if i == 0:
            ops.append(FusedOp(f"r{i}", "matmul", ((dim, dim), (dim, dim)),
                               (dim, dim),
                               fn=(lambda c: lambda v: jnp.tanh(v * c))(c)))
        else:
            ops.append(FusedOp(f"o{i}", "act", ((dim, dim),), (dim, dim),
                               fn=(lambda c: lambda a: jnp.tanh(a) * c)(c)))
    return chain_graph(ops)


def _np_chain(n=5, dim=4):
    """NumPy payloads: not jax-traceable -> composed-Python fallback."""
    ops = [FusedOp(f"c{i}", "cumsum", ((dim, dim),), (dim, dim),
                   fn=lambda a: np.cumsum(a, axis=0) / 2.0)
           for i in range(n)]
    return chain_graph(ops)


def _fork_join():
    w1 = jnp.arange(16.0, dtype=jnp.float32).reshape(4, 4) / 10.0
    ops = [
        FusedOp("src", "matmul", ((4, 4), (4, 4)), (4, 4),
                fn=lambda: jnp.eye(4) @ w1),
        FusedOp("a1", "act", ((4, 4),), (4, 4), fn=jnp.tanh),
        FusedOp("a2", "act", ((4, 4),), (4, 4), fn=jnp.sin),
        FusedOp("join", "add", ((4, 4), (4, 4)), (4, 4),
                fn=lambda x, y: x + y),
    ]
    return OpGraph(ops, edges=[(0, 1), (0, 2), (1, 3), (2, 3)])


# ---------------------------------------------------------------------------
# segment partitioning
# ---------------------------------------------------------------------------


def test_same_lane_runs_become_single_segments(model):
    g = _jax_chain(6)
    ex = ScheduleExecutor(list(EDGE_PUS))
    prog = ex.compile_scheduled(g, {0: "CPU", 1: "CPU", 2: "CPU",
                                    3: "GPU", 4: "GPU", 5: "CPU"})
    s = prog.stats
    assert s["n_segments"] == 3          # CPU run | GPU run | CPU run
    assert s["n_ops"] == 6
    assert [seg.items for seg in prog.segments if seg.lane == "GPU"] \
        == [[(0, 3), (0, 4)]]
    # the GPU segment waits on the first CPU segment; the final CPU
    # segment waits on the GPU one (cross-lane handoff cuts only)
    by_lane = {seg.lane: seg for seg in prog.segments}
    gpu = by_lane["GPU"]
    assert prog.segments[gpu.deps[0]].lane == "CPU"


def test_single_pu_assignment_is_one_segment(model):
    g = _jax_chain(10)
    ex = ScheduleExecutor(list(EDGE_PUS))
    prog = ex.compile_scheduled(g, {i: "CPU" for i in range(10)})
    assert prog.stats["n_segments"] == 1
    assert prog.stats["max_segment_ops"] == 10


def test_coscheduled_steps_force_single_op_barrier_segments(model):
    """Every co-scheduled concurrent step must stay individually
    dispatched (the granularity the contention laws priced)."""
    g0, g1 = _jax_chain(2), _jax_chain(2, salt=0.3)
    sched = ConcurrentSchedule(
        steps=[ConcurrentStep(ops=(0, 0), pus=("CPU", "GPU"), cost=1.0),
               ConcurrentStep(ops=(1, 1), pus=("CPU", "GPU"), cost=1.0)],
        latency=2.0, energy=2.0, objective="latency", mode="joint")
    ex = ScheduleExecutor(list(EDGE_PUS))
    prog = ex.compile_concurrent([g0, g1], sched)
    s = prog.stats
    assert s["n_segments"] == 4 and s["n_barrier"] == 4
    assert s["max_segment_ops"] == 1
    ins = [{0: (_x(),)}, {0: (_x(lo=-1.0),)}]
    got = prog.run(ins)
    for g, i, o in zip((g0, g1), ins, got):
        assert results_bitwise_equal(ex.run_monolithic(g, i), o)


def test_solo_steps_fuse_coscheduled_steps_cut(model):
    """A schedule where request 0 advances alone for 3 ops then both
    requests co-schedule: the solo run fuses, the co-scheduled tail is
    single-op segments."""
    g0, g1 = _jax_chain(4), _jax_chain(1, salt=0.2)
    steps = [ConcurrentStep(ops=(0, None), pus=("CPU", None), cost=1.0),
             ConcurrentStep(ops=(1, None), pus=("CPU", None), cost=1.0),
             ConcurrentStep(ops=(2, None), pus=("CPU", None), cost=1.0),
             ConcurrentStep(ops=(3, 0), pus=("CPU", "GPU"), cost=1.0)]
    sched = ConcurrentSchedule(steps=steps, latency=4.0, energy=4.0,
                               objective="latency", mode="joint")
    ex = ScheduleExecutor(list(EDGE_PUS))
    prog = ex.compile_concurrent([g0, g1], sched)
    s = prog.stats
    assert s["n_segments"] == 3          # fused [0,1,2] | barrier 3 | barrier
    assert s["n_barrier"] == 2
    assert s["max_segment_ops"] == 3


# ---------------------------------------------------------------------------
# fusion modes: jit where bitwise-safe, python fallback otherwise
# ---------------------------------------------------------------------------


def test_jax_payloads_jit_after_first_run(model):
    orch = Orchestrator(model)
    g = _jax_chain(8)
    plan = orch.plan(orch.register(g))
    inputs = {0: (_x(),)}
    orch.execute(plan, inputs)
    prog = orch.program_for(plan, inputs)
    assert prog.stats["n_cold"] == 0
    assert prog.stats["n_jitted"] >= 1
    assert all(seg.mode == JIT for seg in prog.segments)


def test_numpy_payloads_fall_back_to_python(model):
    orch = Orchestrator(model)
    g = _np_chain()
    plan = orch.plan(orch.register(g))
    inputs = {0: (np.random.default_rng(0).standard_normal((4, 4)),)}
    got = orch.execute(plan, inputs)
    prog = orch.program_for(plan, inputs)
    assert all(seg.mode == PYTHON for seg in prog.segments)
    assert results_bitwise_equal(
        orch.executor.run_monolithic(g, inputs), got)


def test_fma_contraction_hazard_falls_back_not_wrong(model):
    """A payload whose mul feeds an add gets FMA-contracted under jit on
    this backend *or* stays bitwise — either way the probe keeps the
    program bitwise-identical to the interpreter."""
    ops = []
    for i in range(6):
        c = jnp.float32(1.0 + 0.01 * i)
        ops.append(FusedOp(f"fma{i}", "act", ((8, 8),), (8, 8),
                           fn=(lambda c: lambda a: a * c + 0.125)(c)))
    g = chain_graph(ops)
    orch = Orchestrator(model)
    plan = orch.plan(orch.register(g))
    inputs = {0: (_x(),)}
    got = orch.execute(plan, inputs)
    assert results_bitwise_equal(
        orch.executor.run_monolithic(g, inputs), got)


def test_none_payload_ops_stay_python_and_return_none(model):
    ops = [FusedOp("a", "act", ((4, 4),), (4, 4), fn=jnp.tanh),
           FusedOp("b", "other", (), (), fn=None),
           FusedOp("c", "act", (), (4, 4), fn=lambda _: jnp.ones((4, 4)))]
    g = chain_graph(ops)
    orch = Orchestrator(model)
    plan = orch.plan(orch.register(g))
    inputs = {0: (_x(4),)}
    got = orch.execute(plan, inputs)
    mono = orch.executor.run_monolithic(g, inputs)
    assert got[1] is None and mono[1] is None
    assert results_bitwise_equal(mono, got)


# ---------------------------------------------------------------------------
# compiled-vs-interpreted bitwise equivalence across all three plan kinds
# ---------------------------------------------------------------------------


def test_sequential_plan_compiled_bitwise_vs_oracle(model):
    orch = Orchestrator(model)
    g = _jax_chain(12)
    plan = orch.plan(orch.register(g))
    assert plan.kind == "sequential"
    inputs = {0: (_x(),)}
    compiled = orch.execute(plan, inputs)
    interp = orch.execute(plan, inputs, compile=False)
    mono = orch.executor.run_monolithic(g, inputs)
    assert results_bitwise_equal(mono, compiled)
    assert results_bitwise_equal(interp, compiled)


def test_parallel_plan_compiled_bitwise_vs_oracle(model):
    orch = Orchestrator(model)
    g = _fork_join()
    plan = orch.plan(orch.register(g))
    assert plan.kind == "parallel"
    compiled = orch.execute(plan)
    interp = orch.execute(plan, compile=False)
    mono = orch.executor.run_monolithic(g)
    assert results_bitwise_equal(mono, compiled)
    assert results_bitwise_equal(interp, compiled)
    np.testing.assert_array_equal(np.asarray(compiled[3]),
                                  np.asarray(mono[3]))


def test_concurrent_plan_compiled_bitwise_vs_isolated(model):
    orch = Orchestrator(model)
    graphs = [_jax_chain(6), _np_chain(5), _jax_chain(4, salt=0.5)]
    plan = orch.plan([orch.register(g) for g in graphs])
    assert plan.kind == "concurrent"
    rng = np.random.default_rng(1)
    ins = [{0: (_x(),)}, {0: (rng.standard_normal((4, 4)),)},
           {0: (_x(lo=-2.0, hi=2.0),)}]
    compiled = orch.execute(plan, ins)
    interp = orch.execute(plan, ins, compile=False)
    for g, i, c, it in zip(graphs, ins, compiled, interp):
        mono = orch.executor.run_monolithic(g, i)
        assert results_bitwise_equal(mono, c)
        assert results_bitwise_equal(it, c)


# ---------------------------------------------------------------------------
# program caching on the orchestrator
# ---------------------------------------------------------------------------


def test_repeat_execute_hits_program_cache(model):
    orch = Orchestrator(model)
    g = _jax_chain(6)
    plan = orch.plan(orch.register(g))
    inputs = {0: (_x(),)}
    orch.execute(plan, inputs)
    assert orch.stats["program_misses"] == 1
    prog = orch.program_for(plan, inputs)       # cache hit, same object
    orch.execute(plan, inputs)
    assert orch.stats["program_misses"] == 1
    assert orch.stats["program_hits"] == 2
    assert orch.program_for(plan, inputs) is prog
    assert prog.runs == 2


def test_input_shape_change_compiles_a_new_program(model):
    orch = Orchestrator(model)
    g = _jax_chain(4)
    plan = orch.plan(orch.register(g))
    orch.execute(plan, {0: (_x(8),)})
    orch.execute(plan, {0: (_x(16),)})
    assert orch.stats["program_misses"] == 2


def test_equal_signature_plans_compile_per_handle(model):
    """Two graphs with identical cost signatures but different payloads
    share a cached *plan*; their compiled programs must not be shared
    (the payloads differ)."""
    orch = Orchestrator(model)
    g1, g2 = _jax_chain(5), _jax_chain(5, salt=0.25)
    h1, h2 = orch.register(g1), orch.register(g2)
    p1 = orch.plan(h1)
    p2 = orch.plan(h2)                  # plan-cache hit, handles re-bound
    assert orch.stats["hits"] >= 1
    inputs = {0: (_x(),)}
    out1 = orch.execute(p1, inputs)
    out2 = orch.execute(p2, inputs)
    assert orch.stats["program_misses"] == 2
    assert results_bitwise_equal(
        orch.executor.run_monolithic(g1, inputs), out1)
    assert results_bitwise_equal(
        orch.executor.run_monolithic(g2, inputs), out2)
    assert not results_bitwise_equal(out1, out2)


def test_rebound_payload_recompiles_instead_of_serving_stale_program(model):
    """Rebinding graph.ops[i].fn after compilation must invalidate the
    cached program — compiled results always match the current payloads
    (and so the compile=False interpreter)."""
    orch = Orchestrator(model)
    g = _jax_chain(5)
    plan = orch.plan(orch.register(g))
    inputs = {0: (_x(),)}
    orch.execute(plan, inputs)
    g.ops[2].fn = lambda a: jnp.sin(a) * 2.0      # new weights, same shape
    got = orch.execute(plan, inputs)
    assert orch.stats["program_misses"] == 2      # recompiled, not served
    assert results_bitwise_equal(
        orch.executor.run_monolithic(g, inputs), got)
    assert results_bitwise_equal(
        orch.execute(plan, inputs, compile=False), got)


def test_program_cache_eviction_closes_worker_pool(model):
    orch = Orchestrator(model, max_cached_programs=1)
    graphs = [_jax_chain(3), _jax_chain(3, salt=0.4)]
    plan = orch.plan([orch.register(g) for g in graphs])
    ins1 = [{0: (_x(8),)}, {0: (_x(8, lo=-1.0),)}]
    ins2 = [{0: (_x(16),)}, {0: (_x(16, lo=-1.0),)}]
    first = orch.program_for(plan, ins1)
    orch.execute(plan, ins1)                      # spins up the pool
    assert first._pool is not None or first.serial_order is not None
    orch.execute(plan, ins2)                      # evicts the first program
    assert len(orch._programs) == 1
    assert first._pool is None                    # pool shut down on evict
    # an evicted program that a caller still holds keeps working
    got = first.run(ins1)
    assert results_bitwise_equal(
        orch.executor.run_monolithic(graphs[0], ins1[0]), got[0])


def test_plan_restored_from_json_executes_compiled(model):
    orch = Orchestrator(model)
    g = _jax_chain(5)
    plan = orch.plan(orch.register(g))
    restored = Plan.from_json(plan.to_json())
    assert restored.cache_key is None   # content-token fallback path
    inputs = {0: (_x(),)}
    got = orch.execute(restored, inputs)
    assert results_bitwise_equal(
        orch.executor.run_monolithic(g, inputs), got)
    # same restored plan again: content token is stable -> cache hit
    orch.execute(restored, inputs)
    assert orch.stats["program_misses"] == 1
    assert orch.stats["program_hits"] == 1


def test_partial_plan_still_rejected_on_compiled_path(model):
    orch = Orchestrator(model)
    g = _jax_chain(6)
    h = orch.register(g)
    orch.admit(h)
    orch.advance(h, 2)
    tail = orch.admit(h)
    with pytest.raises(ValueError,
                       match="does not cover|before its predecessor"):
        orch.execute(tail, [{0: (_x(),)}])


# ---------------------------------------------------------------------------
# error propagation (no deadlock, original exception surfaces)
# ---------------------------------------------------------------------------


def _boom_graph():
    ops = [FusedOp("a", "act", ((4, 4),), (4, 4), fn=jnp.tanh),
           FusedOp("boom", "act", ((4, 4),), (4, 4),
                   fn=lambda a: (_ for _ in ()).throw(
                       RuntimeError("payload exploded"))),
           FusedOp("c", "act", ((4, 4),), (4, 4), fn=jnp.sin)]
    return chain_graph(ops)


def test_compiled_run_propagates_original_exception(model):
    orch = Orchestrator(model)
    g = _boom_graph()
    plan = orch.plan(orch.register(g))
    with pytest.raises(RuntimeError, match="payload exploded"):
        orch.execute(plan, {0: (_x(4),)})


def test_compiled_concurrent_error_does_not_deadlock_other_lanes(model):
    orch = Orchestrator(model)
    graphs = [_jax_chain(4), _boom_graph()]
    plan = orch.plan([orch.register(g) for g in graphs])
    with pytest.raises(RuntimeError, match="payload exploded"):
        orch.execute(plan, [{0: (_x(),)}, {0: (_x(4),)}])
