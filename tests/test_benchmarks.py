"""Every benchmark module's paper-claim checks must pass, and the
paper-zoo graphs must be structurally sound."""
import pytest

from repro.core.paperzoo import ZOO_NAMES, zoo


def test_zoo_has_19_configs():
    z = zoo()
    assert len(z) == 19
    assert set(z) == set(ZOO_NAMES)


def test_zoo_graphs_acyclic_and_sized():
    # fused-op counts in the ballpark of the paper's Table 1
    expect = {"ResNet-50 FP16": (50, 90), "ViT-B/16 FP16": (100, 200),
              "LLaMA-7B(1L) FP16": (10, 16), "BitNet FP16": (30, 42),
              "Mamba-370M FP16": (40, 70), "Hyena FP16": (380, 520),
              "KAN FP16": (15, 30), "SNN-VGG9 FP16": (80, 100),
              "LAVISH FP16": (10, 20), "pi0.5": (4000, 5000)}
    for name, g in zoo().items():
        g.topo_order()   # raises on cycles
        if name in expect:
            lo, hi = expect[name]
            assert lo <= len(g) <= hi, (name, len(g))


def test_kan_unsupported_on_npu():
    z = zoo()
    from repro.core import EdgeSoCCostModel
    table = EdgeSoCCostModel().build_table(z["KAN FP16"])
    for i in range(len(z["KAN FP16"])):
        assert "NPU" not in table.supported_pus(i)


def test_pi05_no_gpu_on_prefix_stage():
    z = zoo()
    g = z["pi0.5"]
    from repro.core import EdgeSoCCostModel
    table = EdgeSoCCostModel().build_table(g)
    prefix_ops = [i for i, op in enumerate(g.ops)
                  if op.name.startswith(("pre.", "dn"))]
    assert prefix_ops
    assert all("GPU" not in table.supported_pus(i) for i in prefix_ops)


@pytest.mark.parametrize("module", [
    "fig2_op_affinity", "fig3_matmul_sweep", "fig4_parallel_pairs",
    "table2_sequential", "fig6_energy", "table3_parallel",
])
def test_benchmark_claims(module):
    import importlib
    mod = importlib.import_module(f"benchmarks.{module}")
    out = mod.run(verbose=False)
    failed = [c for c, ok in out["checks"].items() if not ok]
    assert not failed, failed


@pytest.mark.slow
def test_fig8_concurrent_claims():
    from benchmarks import fig8_concurrent
    out = fig8_concurrent.run(verbose=False)
    failed = [c for c, ok in out["checks"].items() if not ok]
    assert not failed, failed


def test_fig8_multi_model_claims_smoke():
    """M = 3 zoo sweep (sampled, coarsened) runs end-to-end with the
    executor verification check passing."""
    from benchmarks import fig8_concurrent
    out = fig8_concurrent.run_multi(verbose=False, n_models=3, limit=3,
                                    max_segments=24)
    failed = [c for c, ok in out["checks"].items() if not ok]
    assert not failed, failed
    assert out["n_models"] == 3 and out["n_combos"] == 3
    assert sum(out["solver_modes"].values()) == 3


def test_tpu_autoshard_claims():
    from benchmarks import tpu_autoshard
    out = tpu_autoshard.run(verbose=False)
    failed = [c for c, ok in out["checks"].items() if not ok]
    assert not failed, failed


def test_bench_exec_smoke_bitwise_gate():
    """bench_exec smoke: the bitwise compiled-vs-monolithic check must
    hold (it is a correctness claim; the wall-clock overhead ratio is
    asserted only in the full run, where repeats de-noise it)."""
    from benchmarks import bench_exec
    out = bench_exec.run(verbose=False, smoke=True, out_path=None)
    assert all(r["bitwise_vs_monolithic"]
               for r in {**out["models"], **out["concurrent_m"]}.values())
    # every segment settled into a mode (no cold leftovers) and the
    # compiled path really fused: fewer segments than ops on the chains
    for name, r in out["models"].items():
        assert r["program"]["n_cold"] == 0
        assert r["program"]["n_segments"] < r["n_ops"], name
