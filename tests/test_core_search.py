"""Core search-engine tests: optimality, Dijkstra==DP, phases, concurrency.

Property-style tests use seeded randomized sweeps (the offline container has
no `hypothesis` package; invariants are the same).
"""
import itertools

import numpy as np
import pytest

from repro.core import (ContentionModel, EdgeSoCCostModel, FusedOp, OpGraph,
                        chain_graph, evaluate_sequential, sequential_dp,
                        single_pu_cost, solve_concurrent_aligned,
                        solve_concurrent_joint, solve_parallel,
                        solve_sequential)
from repro.core.costmodel import EDGE_PUS
from repro.core.graph import build_sequential_graph
from repro.core.search import dijkstra

KINDS = ["matmul", "conv2d", "dwconv", "add", "rdft", "cumsum", "gather",
         "norm", "act", "softmax"]


def random_chain(rng: np.random.Generator, n: int, npu_unsupported_frac=0.0):
    ops = []
    for i in range(n):
        kind = KINDS[rng.integers(len(KINDS))]
        sz = int(rng.integers(32, 512))
        if kind in ("matmul", "conv2d"):
            op = FusedOp(name=f"op{i}", kind="matmul",
                         in_shapes=((1, sz, sz), (sz, sz)), out_shape=(1, sz, sz))
        else:
            numel = int(rng.integers(1_000, 2_000_000))
            op = FusedOp(name=f"op{i}", kind=kind, in_shapes=((numel,),),
                         out_shape=(numel,))
        if rng.random() < npu_unsupported_frac:
            op.meta["unsupported_on"] = ("NPU",)
        ops.append(op)
    return chain_graph(ops)


def brute_force_sequential(chain, ops, table, pus, objective):
    """Exhaustive search over all K^N assignments."""
    best = (float("inf"), None)
    sup = [table.supported_pus(oi) for oi in chain]
    for assign in itertools.product(*sup):
        lat, eng = evaluate_sequential(chain, list(assign), ops, table, pus)
        key = lat if objective == "latency" else eng
        if key < best[0]:
            best = (key, list(assign))
    return best


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_sequential_optimality_vs_bruteforce(seed, objective):
    rng = np.random.default_rng(seed)
    g = random_chain(rng, n=6, npu_unsupported_frac=0.2)
    model = EdgeSoCCostModel()
    table = model.build_table(g)
    chain = list(range(len(g)))
    sched = solve_sequential(chain, g.ops, table, EDGE_PUS, objective)
    bf_cost, bf_assign = brute_force_sequential(chain, g.ops, table, EDGE_PUS, objective)
    got = sched.latency if objective == "latency" else sched.energy
    assert got == pytest.approx(bf_cost, rel=1e-9), (
        f"search={got} brute={bf_cost} assign={sched.assignment} vs {bf_assign}")


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_dijkstra_equals_dp(seed, objective):
    rng = np.random.default_rng(100 + seed)
    g = random_chain(rng, n=int(rng.integers(2, 30)), npu_unsupported_frac=0.1)
    model = EdgeSoCCostModel()
    table = model.build_table(g)
    chain = list(range(len(g)))
    eg = build_sequential_graph(chain, g.ops, table, EDGE_PUS, objective)
    c1, a1 = dijkstra(eg)
    c2, a2 = sequential_dp(chain, g.ops, table, EDGE_PUS, objective)
    assert c1 == pytest.approx(c2, rel=1e-12)
    # assignments may differ on exact ties; costs must agree when re-evaluated
    l1, e1 = evaluate_sequential(chain, a1, g.ops, table, EDGE_PUS)
    l2, e2 = evaluate_sequential(chain, a2, g.ops, table, EDGE_PUS)
    key = (l1, l2) if objective == "latency" else (e1, e2)
    assert key[0] == pytest.approx(key[1], rel=1e-12)


def test_bident_never_worse_than_best_single_pu():
    rng = np.random.default_rng(7)
    for _ in range(10):
        g = random_chain(rng, n=12)
        model = EdgeSoCCostModel()
        table = model.build_table(g)
        chain = list(range(len(g)))
        sched = solve_sequential(chain, g.ops, table, EDGE_PUS, "latency")
        singles = [single_pu_cost(chain, p, g.ops, table, EDGE_PUS)
                   for p in EDGE_PUS]
        best_single = min(s[0] for s in singles if s is not None)
        assert sched.latency <= best_single + 1e-12


def test_energy_schedule_never_worse_energy():
    """Paper Fig. 6: energy-optimal schedule always reduces energy vs the
    best single-PU *energy* baseline."""
    rng = np.random.default_rng(11)
    for _ in range(10):
        g = random_chain(rng, n=10)
        model = EdgeSoCCostModel()
        table = model.build_table(g)
        chain = list(range(len(g)))
        sched = solve_sequential(chain, g.ops, table, EDGE_PUS, "energy")
        singles = [single_pu_cost(chain, p, g.ops, table, EDGE_PUS)
                   for p in EDGE_PUS]
        best_single_energy = min(s[1] for s in singles if s is not None)
        assert sched.energy <= best_single_energy + 1e-12


def test_unsupported_ops_route_around():
    """Ops unsupported on a PU never get assigned there (paper §3.1: the
    graph builder creates no node, the search routes around)."""
    ops = [FusedOp(name=f"m{i}", kind="matmul", in_shapes=((1, 256, 256), (256, 256)),
                   out_shape=(1, 256, 256),
                   meta={"unsupported_on": ("GPU", "NPU")} if i % 2 else {})
           for i in range(6)]
    g = chain_graph(ops)
    table = EdgeSoCCostModel().build_table(g)
    sched = solve_sequential(list(range(6)), g.ops, table, EDGE_PUS, "latency")
    for i, pu in enumerate(sched.assignment):
        if i % 2:
            assert pu == "CPU"


# ---------------------------------------------------------------------------
# Phase partitioning + parallel search
# ---------------------------------------------------------------------------


def diamond_graph():
    """fork -> (branch A: 2 ops | branch B: 1 op) -> join."""
    ops = [FusedOp(name=f"o{i}", kind="matmul",
                   in_shapes=((1, 256, 256), (256, 256)), out_shape=(1, 256, 256))
           for i in range(5)]
    ops[2] = FusedOp(name="o2", kind="cumsum", in_shapes=((500_000,),),
                     out_shape=(500_000,))
    edges = [(0, 1), (0, 2), (1, 3), (2, 4)]
    # o1->o3 chain (branch A), o2->o4? make B: just o2; join at 4: edges (3,4),(2,4)
    edges = [(0, 1), (1, 3), (0, 2), (3, 4), (2, 4)]
    return OpGraph(ops, edges)


def test_phase_partitioning():
    g = diamond_graph()
    phases = g.phases()
    # phase 0: [o0]; phase 1: branches [o1,o3] and [o2]; phase 2: [o4]
    assert len(phases) == 3
    assert not phases[0].concurrent
    assert phases[1].concurrent and len(phases[1].branches) == 2
    branch_sets = sorted(tuple(b.ops) for b in phases[1].branches)
    assert branch_sets == [(1, 3), (2,)]
    assert not phases[2].concurrent


def test_parallel_no_worse_than_sequential():
    g = diamond_graph()
    table = EdgeSoCCostModel().build_table(g)
    par = solve_parallel(g, table, EDGE_PUS)
    # sequential cost: solve each branch independently and sum
    seq_total = 0.0
    for ph in g.phases():
        for br in ph.branches:
            s = solve_sequential(br.ops, g.ops, table, EDGE_PUS)
            seq_total += s.latency
    assert par.latency <= seq_total + 1e-12
    assert par.n_concurrent_phases >= 1


def test_single_chain_has_no_concurrent_phases():
    rng = np.random.default_rng(3)
    g = random_chain(rng, 10)
    table = EdgeSoCCostModel().build_table(g)
    par = solve_parallel(g, table, EDGE_PUS)
    assert par.n_concurrent_phases == 0
    seq = solve_sequential(list(range(10)), g.ops, table, EDGE_PUS)
    assert par.latency == pytest.approx(seq.latency, rel=1e-9)


def test_contention_slowdown_applied():
    g = diamond_graph()
    table = EdgeSoCCostModel().build_table(g)
    hot = ContentionModel(sf={(a, b): 5.0 for a in EDGE_PUS for b in EDGE_PUS
                              if a != b})
    cool = ContentionModel(sf={})
    p_hot = solve_parallel(g, table, EDGE_PUS, contention=hot)
    p_cool = solve_parallel(g, table, EDGE_PUS, contention=cool)
    assert p_hot.latency >= p_cool.latency


# ---------------------------------------------------------------------------
# Multi-model concurrent search
# ---------------------------------------------------------------------------


def brute_force_joint(chain0, table0, chain1, table1, cm):
    """Exhaustive enumeration of interleavings x PU choices (tiny sizes)."""
    from functools import lru_cache

    @lru_cache(maxsize=None)
    def best(i, j):
        if i == len(chain0) and j == len(chain1):
            return 0.0
        cands = []
        if i < len(chain0) and j < len(chain1):
            o0, o1 = chain0[i], chain1[j]
            for d0 in table0.supported_pus(o0):
                t0 = table0.require(o0, d0).w
                for d1 in table1.supported_pus(o1):
                    t1 = table1.require(o1, d1).w
                    cands.append(cm.pair_step_cost(t0, d0, t1, d1) + best(i + 1, j + 1))
        if i < len(chain0):
            o0 = chain0[i]
            cands += [table0.require(o0, d).w + best(i + 1, j)
                      for d in table0.supported_pus(o0)]
        if j < len(chain1):
            o1 = chain1[j]
            cands += [table1.require(o1, d).w + best(i, j + 1)
                      for d in table1.supported_pus(o1)]
        return min(cands)

    return best(0, 0)


@pytest.mark.parametrize("seed", range(6))
def test_joint_dijkstra_optimal(seed):
    rng = np.random.default_rng(200 + seed)
    g0 = random_chain(rng, int(rng.integers(2, 5)))
    g1 = random_chain(rng, int(rng.integers(2, 5)))
    m = EdgeSoCCostModel()
    t0, t1 = m.build_table(g0), m.build_table(g1)
    cm = ContentionModel()
    sched = solve_concurrent_joint(list(range(len(g0))), t0,
                                   list(range(len(g1))), t1, EDGE_PUS, cm)
    bf = brute_force_joint(tuple(range(len(g0))), t0,
                           tuple(range(len(g1))), t1, cm)
    assert sched.latency == pytest.approx(bf, rel=1e-9)


def test_joint_no_worse_than_serial():
    """Concurrent co-scheduling beats serial best-single-PU execution
    (paper Fig. 8: geomean 3.42x over homogeneous serial)."""
    rng = np.random.default_rng(42)
    g0 = random_chain(rng, 8)
    g1 = random_chain(rng, 8)
    m = EdgeSoCCostModel()
    t0, t1 = m.build_table(g0), m.build_table(g1)
    sched = solve_concurrent_joint(list(range(8)), t0, list(range(8)), t1,
                                   EDGE_PUS)
    serial = 0.0
    for g, t in ((g0, t0), (g1, t1)):
        singles = [single_pu_cost(list(range(8)), p, g.ops, t, EDGE_PUS)
                   for p in EDGE_PUS]
        serial += min(s[0] for s in singles if s is not None)
    # joint Dijkstra can always fall back to pure solo steps == BIDENT
    # sequential <= best single PU, so this must hold.
    assert sched.latency <= serial + 1e-12


def test_aligned_lockstep_structure():
    rng = np.random.default_rng(5)
    g0 = random_chain(rng, 6)
    g1 = random_chain(rng, 9)
    m = EdgeSoCCostModel()
    t0, t1 = m.build_table(g0), m.build_table(g1)
    sched = solve_concurrent_aligned(list(range(6)), t0, list(range(9)), t1,
                                     EDGE_PUS)
    assert len(sched.steps) == 9  # 6 lockstep + 3 solo tail
    for st in sched.steps[:6]:
        assert st.ops[0] is not None and st.ops[1] is not None
    for st in sched.steps[6:]:
        assert st.ops[0] is None and st.ops[1] is not None
    assert sched.latency > 0


def test_joint_beats_or_matches_aligned():
    """The joint (i,j) state space strictly contains the aligned one, so
    its optimum can only be <=."""
    rng = np.random.default_rng(9)
    for _ in range(5):
        g0 = random_chain(rng, 5)
        g1 = random_chain(rng, 5)
        m = EdgeSoCCostModel()
        t0, t1 = m.build_table(g0), m.build_table(g1)
        a = solve_concurrent_aligned(list(range(5)), t0, list(range(5)), t1, EDGE_PUS)
        j = solve_concurrent_joint(list(range(5)), t0, list(range(5)), t1, EDGE_PUS)
        assert j.latency <= a.latency + 1e-12
