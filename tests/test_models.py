"""Model-zoo correctness: per-arch smoke tests + decode/forward consistency
+ layer-level oracle equivalence (property-style seeded sweeps)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import layers as L
from repro.models import model as M

jax.config.update("jax_enable_x64", False)


def make_batch(cfg, B, T, rng):
    batch = {}
    if cfg.block_pattern == "encdec":
        batch["embeds"] = jnp.asarray(rng.standard_normal((B, T, cfg.d_model)),
                                      jnp.float32) * 0.1
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)))
    elif cfg.modality_stub:
        batch["embeds"] = jnp.asarray(rng.standard_normal((B, T, cfg.d_model)),
                                      jnp.float32) * 0.1
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)))
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)))
    return batch


# ---------------------------------------------------------------------------
# per-arch smoke: forward + one SGD train step on CPU, reduced config
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 32
    batch = make_batch(cfg, B, T, rng)
    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (B, T, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    @jax.jit
    def step(p):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(p)
        p = jax.tree.map(lambda w, g: w - 1e-3 * g.astype(w.dtype), p, grads)
        return p, loss

    p1, loss1 = step(params)
    p2, loss2 = step(p1)
    assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
    assert float(loss2) < float(loss1) + 1.0  # sane magnitude, no blowup


# ---------------------------------------------------------------------------
# prefill + decode == full forward (the serving path is numerically the
# training path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(1)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, T = 2, 12
    n_pre = 8
    batch = make_batch(cfg, B, T, rng)
    full_logits, _ = M.forward(cfg, params, batch)

    pre_batch = {k: (v[:, :n_pre] if k != "embeds" or cfg.block_pattern != "encdec"
                     else v)
                 for k, v in batch.items() if k != "labels"}
    if cfg.block_pattern == "encdec":
        # encoder sees the full memory; decoder prompt is the prefix
        pre_batch = {"embeds": batch["embeds"],
                     "tokens": batch["tokens"][:, :n_pre]}
    logits_pre, cache = M.prefill(cfg, params, pre_batch, max_len=T)
    np.testing.assert_allclose(np.asarray(logits_pre[:, -1]),
                               np.asarray(full_logits[:, n_pre - 1]),
                               rtol=2e-2, atol=2e-3)
    # decode the remaining tokens one at a time
    for t in range(n_pre, T):
        if cfg.block_pattern == "encdec":
            dec_in = {"tokens": batch["tokens"][:, t:t + 1]}
        elif cfg.modality_stub:
            dec_in = {"embeds": batch["embeds"][:, t:t + 1]}
        else:
            dec_in = {"tokens": batch["tokens"][:, t:t + 1]}
        logits_t, cache = M.decode_step(cfg, params, cache, dec_in)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-3,
            err_msg=f"{arch}: decode step {t} diverges from forward")


# ---------------------------------------------------------------------------
# layer oracles (property-style sweeps)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("shape", [(1, 64, 4, 2, 16), (2, 96, 8, 8, 32),
                                   (1, 130, 6, 3, 8)])
def test_flash_ref_matches_plain(seed, shape):
    B, T, Hq, Hk, D = shape
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, T, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hk, D)), jnp.float32)
    for causal in (True, False):
        ref = L.plain_attention(q, k, v, causal=causal)
        out = L.flash_attention_ref(q, k, v, causal=causal,
                                    q_chunk=32, kv_chunk=48)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def naive_linear_recurrence(c, b, v, log_a):
    B, T, H, N = b.shape
    P = v.shape[-1]
    S = np.zeros((B, H, N, P))
    ys = []
    for t in range(T):
        a = np.exp(log_a[:, t])[..., None, None]
        S = S * a + np.einsum("bhn,bhp->bhnp", b[:, t], v[:, t])
        ys.append(np.einsum("bhn,bhnp->bhp", c[:, t], S))
    return np.stack(ys, 1), S


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("dims", [(1, 32, 2, 4, 8, 8), (2, 50, 3, 8, 4, 16)])
def test_chunked_recurrence_matches_naive(seed, dims):
    B, T, H, N, P, chunk = dims
    rng = np.random.default_rng(10 + seed)
    c = rng.standard_normal((B, T, H, N)).astype(np.float32)
    b = rng.standard_normal((B, T, H, N)).astype(np.float32)
    v = rng.standard_normal((B, T, H, P)).astype(np.float32)
    log_a = -np.abs(rng.standard_normal((B, T, H))).astype(np.float32) * 0.5
    y, S = L.chunked_linear_recurrence(jnp.asarray(c), jnp.asarray(b),
                                       jnp.asarray(v), jnp.asarray(log_a),
                                       chunk=chunk)
    y_ref, S_ref = naive_linear_recurrence(c, b, v, log_a)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-4, atol=2e-4)


def test_recurrence_step_matches_chunked_tail():
    """decode single-step == last step of the chunked full-sequence path."""
    rng = np.random.default_rng(3)
    B, T, H, N, P = 2, 17, 2, 4, 8
    c = rng.standard_normal((B, T, H, N)).astype(np.float32)
    b = rng.standard_normal((B, T, H, N)).astype(np.float32)
    v = rng.standard_normal((B, T, H, P)).astype(np.float32)
    log_a = -np.abs(rng.standard_normal((B, T, H))).astype(np.float32) * 0.3
    y_all, S_all = L.chunked_linear_recurrence(
        jnp.asarray(c), jnp.asarray(b), jnp.asarray(v), jnp.asarray(log_a),
        chunk=8)
    # run first T-1 via chunked, then the last step via the decode kernel
    y_head, S_head = L.chunked_linear_recurrence(
        jnp.asarray(c[:, :-1]), jnp.asarray(b[:, :-1]), jnp.asarray(v[:, :-1]),
        jnp.asarray(log_a[:, :-1]), chunk=8)
    y_last, S_last = L.linear_recurrence_step(
        S_head, jnp.asarray(c[:, -1]), jnp.asarray(b[:, -1]),
        jnp.asarray(v[:, -1]), jnp.asarray(log_a[:, -1]))
    np.testing.assert_allclose(np.asarray(y_last), np.asarray(y_all[:, -1]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_last), np.asarray(S_all),
                               rtol=2e-4, atol=2e-4)


def test_moe_no_drop_equals_explicit_topk():
    """With generous capacity, the dispatch-einsum MoE equals an explicit
    per-token top-k mixture."""
    cfg = get_config("granite-moe-1b-a400m").reduced()
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    rng = np.random.default_rng(5)
    key = jax.random.PRNGKey(2)
    p = L.moe_init(key, cfg, jnp.float32)
    B, T = 2, 16
    x = jnp.asarray(rng.standard_normal((B, T, cfg.d_model)), jnp.float32) * 0.3
    from repro.sharding import NO_POLICY
    out, aux = L.moe_block(p, x, cfg, NO_POLICY)

    # explicit reference
    xf = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xf @ np.asarray(p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    ref = np.zeros_like(xf)
    for n in range(xf.shape[0]):
        topk = np.argsort(probs[n])[::-1][:cfg.moe_top_k]
        gv = probs[n][topk]
        gv = gv / gv.sum()
        for e, g in zip(topk, gv):
            h = xf[n] @ np.asarray(p["w_up"][e])
            gate, up = np.split(h, 2)
            act = gate / (1 + np.exp(-gate)) * up
            ref[n] += g * (act @ np.asarray(p["w_down"][e]))
    ref = ref.reshape(B, T, cfg.d_model)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_mla_absorbed_decode_equals_expanded():
    """MLA weight-absorbed decode scoring == expanded-form attention."""
    cfg = get_config("deepseek-v3-671b").reduced()
    key = jax.random.PRNGKey(7)
    p = L.mla_init(key, cfg, jnp.float32)
    rng = np.random.default_rng(7)
    B, T = 2, 9
    x = jnp.asarray(rng.standard_normal((B, T, cfg.d_model)), jnp.float32) * 0.2
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    from repro.sharding import NO_POLICY
    out_full, _ = L.mla_attention(p, x, cfg, NO_POLICY, positions=pos)

    # replay token-by-token through the latent cache
    cache = {"c_kv": jnp.zeros((B, T, cfg.kv_lora_rank), jnp.float32),
             "k_pe": jnp.zeros((B, T, cfg.qk_rope_head_dim), jnp.float32),
             "len": jnp.zeros((), jnp.int32)}
    outs = []
    for t in range(T):
        o, cache = L.mla_attention(p, x[:, t:t + 1], cfg, NO_POLICY,
                                   positions=pos[:, t:t + 1], cache=cache)
        outs.append(o)
    out_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(out_full),
                               rtol=2e-3, atol=2e-3)


def test_mrope_equals_rope_when_streams_equal():
    rng = np.random.default_rng(8)
    B, T, H, D = 2, 16, 4, 32
    x = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
    p = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    cos1, sin1 = L.rope_cos_sin(p, D, 1e4)
    cos3, sin3 = L.mrope_cos_sin(jnp.stack([p, p, p]), D, 1e4,
                                 sections=(4, 6, 6))
    np.testing.assert_allclose(np.asarray(cos1), np.asarray(cos3), rtol=1e-6)
    r1 = L.apply_rope(x, cos1, sin1)
    r3 = L.apply_rope(x, cos3, sin3)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r3), rtol=1e-6)
