"""BENCH_backend: the profile -> plan -> execute -> measure loop on real
heterogeneous backends.

Everything before this benchmark priced PU lanes analytically; here the
loop closes end-to-end on actual executing code.  The kernel-backed zoo
chain (``modelgraph.kernel_chain``: attention / SSD scan / MoE Pallas
payloads interleaved with host-affine glue) is

1. **profiled** per target — ``MeasuredProfiler(targets=...)`` times every
   op's dialect payload on each of the three builtin backends
   (`numpy-eager`, `xla-cpu`, `pallas-interpret`) with fenced
   ``block_until_ready`` timing;
2. **planned** from those measured cells — the sequential DP routes ops
   across target lanes, pricing lane switches at each target's declared
   ``handoff_s``;
3. **executed** as a compiled :class:`LaneProgram` on the bound backends —
   per-segment variant payloads probe-verified against the reference
   composition before serving (bitwise where the probe passes, per-dtype
   tolerance where the target declares one);
4. **measured** wall-clock and gated against the best single-target run.

Serving policy (recorded in the output): the heterogeneous plan is served
only when its predicted win over the best single target clears
``HET_MARGIN`` — the per-op cost cells cannot see segment fusion, so a
sub-margin predicted win is noise, and the serving route falls back to the
best single target (making the het-vs-single latency gate exact by
construction in that regime, and a real measured win outside it).

Checks (all gate, including --smoke):

* >= 3 targets produce real measured per-op costs;
* the plan built from measured costs is bitwise-reproducible across
  fresh orchestrators;
* every compiled program's outputs match the interpreter oracle —
  bitwise when no tolerance-verified segment is involved, else within
  the f32 variant tolerance;
* the forced all-Pallas program serves only probe-verified variant
  segments (and actually exercises >= 1 variant);
* measured e2e latency of the served route <= 1.0x the best measured
  single target.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import schedule_to_dict
from repro.core.backends import default_registry
from repro.core.modelgraph import kernel_chain
from repro.core.orchestrator import Orchestrator
from repro.core.profiler import MeasuredProfiler
from repro.core.targets import variant_tolerance

from .common import env_meta

LANES = ("numpy-eager", "xla-cpu", "pallas-interpret")
HET_MARGIN = 0.10      # predicted het win required before serving het
SMOKE_CFG = dict(blocks=1, seq=64, heads=2, head_dim=16, state=8,
                 moe_ff=16, chunk=32, block_q=32, block_k=32)
FULL_CFG = dict(blocks=2, seq=512, heads=4, head_dim=64, state=16,
                moe_ff=64, chunk=64, block_q=64, block_k=64,
                block_m=32, block_f=32)


def _measure_program(prog, ext, repeats: int) -> dict:
    """Warm, then fenced best/median-of-repeats wall-clock of one
    compiled program (first run settles probe verification)."""
    import jax
    jax.block_until_ready(prog.run(ext))     # cold: probe + settle
    jax.block_until_ready(prog.run(ext))     # warm-up
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(prog.run(ext))
        times.append(time.perf_counter() - t0)
    return {"best": min(times), "median": sorted(times)[len(times) // 2]}


def _outputs_match(got: dict, ref: dict, stats: dict) -> tuple[bool, str]:
    """Compiled-vs-oracle comparison at the strictness the program's own
    verification records justify: bitwise unless a segment was admitted
    under tolerance (variant payloads or a declared-tolerance jit), in
    which case the per-dtype variant tolerance applies end-to-end."""
    if set(got) != set(ref):
        return False, "result keys differ"
    verdicts = list(stats.get("variant_verified", {}).values()) \
        + list(stats.get("jit_verified", {}).values())
    strict = all(v == "bitwise" for v in verdicts)
    for k in sorted(ref):
        a, b = np.asarray(ref[k]), np.asarray(got[k])
        if a.shape != b.shape:
            return False, f"op {k}: shape {b.shape} != {a.shape}"
        if strict:
            if a.dtype != b.dtype or a.tobytes() != b.tobytes():
                return False, f"op {k}: not bitwise"
        else:
            atol, rtol = variant_tolerance(a.dtype)
            if not np.allclose(a.astype(np.float64), b.astype(np.float64),
                               atol=atol, rtol=rtol):
                err = float(np.max(np.abs(a.astype(np.float64)
                                          - b.astype(np.float64))))
                return False, f"op {k}: max err {err:.2e} > tol {atol:g}"
    return True, "bitwise" if strict else "tolerance"


def run(verbose: bool = True, smoke: bool = False,
        out_path: str | None = "BENCH_backend.json") -> dict:
    cfg = dict(SMOKE_CFG if smoke else FULL_CFG)
    repeats = 5 if smoke else 9
    graph, ext = kernel_chain(**cfg)
    n = len(graph)

    reg = default_registry()
    binding = {name: reg.get(name) for name in LANES}
    if verbose:
        print(f"registry: {reg.names()}  (bound lanes: {list(LANES)})")

    # -- 1. profile: measured per-(op, target) cells --------------------
    t0 = time.time()
    prof = MeasuredProfiler(warmup=1, iters=3 if smoke else 5,
                            targets=binding)
    table = prof.profile(graph)
    t_profile = time.time() - t0
    measurements = table.meta.get("measurements", {})
    failures = table.meta.get("profile_failures", {})
    targets_measured = sorted({lane for (_, lane) in measurements})
    ops_measured = {i for (i, _) in measurements}

    # -- 2. plan from measured costs ------------------------------------
    orch = Orchestrator(table, targets=binding)
    h = orch.register(graph)
    plan = orch.plan(h)
    plan_b = Orchestrator(table, targets=binding)
    plan2 = plan_b.plan(plan_b.register(graph))
    plan_repro = (schedule_to_dict(plan.schedule)
                  == schedule_to_dict(plan2.schedule))

    wl = orch.workload(h)
    best_pu, best_pred, pred_by_pu = wl.best_solo("latency")
    het_route = tuple(lane for _, lane in plan.route[0])
    het_is_single = len(set(het_route)) == 1
    pred_win = 1.0 - plan.latency / best_pred
    serve_het = (not het_is_single) and pred_win >= HET_MARGIN
    served_route = het_route if serve_het or het_is_single \
        else (best_pu,) * n

    # -- 3 + 4. execute compiled programs on the bound backends ---------
    ref_outs = orch.executor.run_monolithic(graph, ext)

    def compile_route(route):
        if route == het_route:
            return orch.program_for(plan)
        return orch.executor.compile_scheduled(
            graph, {i: route[i] for i in range(n)})

    candidates = {het_route, served_route}
    candidates.update((lane,) * n for lane in LANES)
    rows = {}
    for route in sorted(candidates):
        prog = compile_route(route)
        lat = _measure_program(prog, ext, repeats)
        got = prog.run(ext)
        ok, how = _outputs_match(got, ref_outs, prog.stats)
        rows[route] = {"latency": lat, "match": ok, "match_how": how,
                       "stats": prog.stats}
        if verbose:
            print(f"  route {'/'.join(sorted(set(route)))}"
                  f"[{len(prog.stats['variant_verified'] or {})}v]"
                  f": best {1e3 * lat['best']:8.3f}ms"
                  f"  median {1e3 * lat['median']:8.3f}ms"
                  f"  match={how if ok else 'FAIL: ' + how}")

    singles = {r[0]: rows[r]["latency"]["best"]
               for r in rows if len(set(r)) == 1}
    best_single_meas = min(singles.values())
    served_meas = rows[served_route]["latency"]["best"]
    het_meas = rows[het_route]["latency"]["best"]
    ratio = served_meas / best_single_meas

    # forced all-Pallas route exercises kernel-variant probe verification
    pallas_stats = rows[("pallas-interpret",) * n]["stats"]
    pallas_verdicts = set(pallas_stats["variant_verified"].values())
    pallas_gate = (pallas_stats["n_variant"] >= 1
                   and pallas_verdicts <= {"bitwise", "tolerance"}
                   and rows[("pallas-interpret",) * n]["match"])

    checks = {
        ">= 3 targets profiled with measured per-op costs "
        f"({len(targets_measured)} targets, {len(failures)} failures)":
            len(targets_measured) >= 3 and len(ops_measured) == n,
        "plan from measured costs is bitwise-reproducible across fresh "
        "solves": plan_repro,
        "every compiled program matches the interpreter oracle "
        "(bitwise, or within variant tolerance where a segment was "
        "tolerance-verified)": all(r["match"] for r in rows.values()),
        "forced all-Pallas program serves only probe-verified kernel "
        f"variants (verdicts: {sorted(pallas_verdicts)})": pallas_gate,
        "measured served-route e2e <= 1.0x best single target "
        f"({1e3 * served_meas:.3f}ms vs {1e3 * best_single_meas:.3f}ms)":
            ratio <= 1.0,
    }

    out = {
        "smoke": smoke, "config": cfg, "repeats": repeats,
        "profile_s": t_profile,
        "targets_measured": targets_measured,
        "profile_failures": {f"{i}/{lane}": msg
                             for (i, lane), msg in failures.items()},
        "op_costs_us": {
            f"{i}.{graph.ops[i].name}": {
                lane: round(1e6 * m["median"], 2)
                for (j, lane), m in measurements.items() if j == i}
            for i in range(n)},
        "plan": {
            "route": [list(r) for r in plan.route[0]],
            "predicted_latency_s": plan.latency,
            "predicted_best_single": {"pu": best_pu, "latency_s": best_pred,
                                      "per_pu": pred_by_pu},
            "predicted_win": pred_win,
            "het_margin": HET_MARGIN,
            "served_het": served_route == het_route and not het_is_single,
            "served_route": list(served_route),
            "reproducible": plan_repro,
        },
        "measured": {
            "/".join(sorted(set(r))) if len(set(r)) > 1 else r[0]: {
                "best_s": v["latency"]["best"],
                "median_s": v["latency"]["median"],
                "match": v["match"], "match_how": v["match_how"],
                "n_jitted": v["stats"]["n_jitted"],
                "n_variant": v["stats"]["n_variant"],
                "variant_verified": {str(k): s for k, s in
                                     v["stats"]["variant_verified"].items()},
                "jit_verified": {str(k): s for k, s in
                                 v["stats"]["jit_verified"].items()},
            } for r, v in rows.items()},
        "het_vs_best_single": het_meas / best_single_meas,
        "served_vs_best_single": ratio,
        "checks": checks,
    }

    if verbose:
        print(f"profile: {t_profile:.1f}s over {len(targets_measured)} "
              f"targets; plan predicted {1e3 * plan.latency:.3f}ms "
              f"(best single {best_pu} {1e3 * best_pred:.3f}ms, "
              f"win {100 * pred_win:.1f}%)")
        print(f"served route: {'/'.join(dict.fromkeys(served_route))} "
              f"-> {1e3 * served_meas:.3f}ms "
              f"({ratio:.3f}x best single)")
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")

    if out_path:
        out["meta"] = env_meta()
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
        if verbose:
            print(f"wrote {out_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI (all checks still gate)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_path = args.out or ("BENCH_backend.smoke.json" if args.smoke
                            else "BENCH_backend.json")
    out = run(verbose=True, smoke=args.smoke, out_path=out_path)
    # every check gates, --smoke included: probe verification and the
    # het-vs-single latency bound are acceptance criteria of the target
    # subsystem, not timing-noise claims (the serving-margin policy makes
    # the latency gate exact when the het win is sub-margin)
    raise SystemExit(0 if all(out["checks"].values()) else 1)
