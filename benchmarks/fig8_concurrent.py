"""Fig. 8: multi-model concurrent orchestration over all 190 unique pairs
of the 19 model-precision configurations, vs homogeneous serial execution
(both models sequentially on their own best single PU).

Same-model pairs use the aligned solver; mixed pairs the joint (i, j)
search (paper §3.2.2).  The sweep runs at **full operator resolution**:
the dense-table A* joint solver (``core.search.solve_concurrent_joint``)
walks the optimal corridor of the progress grid directly, so even the
pi0.5 x Hyena pair (4,334 x 504 ops) solves in ~150 ms.  The
seed's mandatory <= 48-segment coarsening (``common.segment_table``) is
retired as an approximation and kept only as an opt-in fallback
(``max_segments=``/``--max-segments``) for comparison runs.

Claims validated (structural): concurrent geomean clearly exceeds the
sequential geomean; complementary-affinity pairs (CPU-bound KAN/SNN x
GPU-bound LAVISH/ViT) rank near the top; very few pairs fall below 1x;
energy-optimal co-scheduling gives a positive average energy reduction.

Deviation note (EXPERIMENTS.md §Claims): the paper's absolute 3.42x
geomean (range up to 22.4x) reflects serial-baseline effects on real
silicon (per-PU model reload / cache thrash between alternating models)
that a cost-model reproduction has no basis to assume; the analytical
upper bound for co-scheduling two equal-length models over idle PUs
without those effects is ~2-3x.  Scheduling *granularity* is no longer
part of the deviation: these numbers are the exact optima of the cost
model at native operator granularity, and full-resolution results are
the reference for subsequent PRs (the coarsened numbers differ by the
documented approximation error of segment merging, not by search error).
"""
from __future__ import annotations

import itertools
import time

from repro.core import (ContentionModel, DenseCostTable, EDGE_PUS,
                        EdgeSoCCostModel, single_pu_cost,
                        solve_concurrent_aligned, solve_concurrent_joint)
from repro.core.costmodel import STATIC_POWER_W
from repro.core.paperzoo import zoo

from .common import best_single, geomean, segment_table


def run(verbose: bool = True, max_segments: int | None = None) -> dict:
    """Run the 190-pair sweep.

    ``max_segments=None`` (default) schedules at full operator
    resolution; an integer opts back into the seed's segment coarsening.
    """
    model = EdgeSoCCostModel()
    cm = ContentionModel()
    z = zoo()
    names = list(z)
    # Per-config cost tables + serial baselines.  The Fig. 8 baseline is
    # "both models run sequentially on their best single PU" — the energy
    # claim compares against the energy of THAT execution (not against an
    # energy-best serial run), consistent with the paper.
    t_setup = time.time()
    seg = {}
    for name, g in z.items():
        full_table = model.build_table(g)
        full_chain = list(range(len(g)))
        chain, table = (segment_table(g, full_table, max_segments)
                        if max_segments is not None
                        else (full_chain, full_table))
        bpu, bl, _ = best_single(full_chain, g.ops, full_table)
        _, be = single_pu_cost(full_chain, bpu, g.ops, full_table, EDGE_PUS)
        # dense view built once per model, shared by all 19+ pair solves
        dense = DenseCostTable.from_chain(chain, table, EDGE_PUS)
        seg[name] = (chain, table, bl, be, dense)
    t_setup = time.time() - t_setup

    pairs = list(itertools.combinations_with_replacement(names, 2))
    assert len(pairs) == 190, len(pairs)
    speedups = {}
    energy_reds = {}
    t_solve = time.time()
    for a, b in pairs:
        ca, ta, bla, bea, da = seg[a]
        cb, tb, blb, beb, db = seg[b]
        serial = bla + blb
        if a == b:
            sched = solve_concurrent_aligned(ca, ta, cb, tb, EDGE_PUS, cm,
                                             dense0=da, dense1=db)
        else:
            sched = solve_concurrent_joint(ca, ta, cb, tb, EDGE_PUS, cm,
                                           dense0=da, dense1=db)
        speedups[(a, b)] = serial / sched.latency
        se = solve_concurrent_joint(
            ca, ta, cb, tb, EDGE_PUS, cm, objective="energy",
            dense0=da, dense1=db) if a != b else \
            solve_concurrent_aligned(
                ca, ta, cb, tb, EDGE_PUS, cm, objective="energy",
                dense0=da, dense1=db)
        # total window energy = active op energy + package static power
        # over the window: shortening the makespan saves static energy —
        # the dominant source of the paper's concurrent energy reduction.
        # The energy-aware scheduler picks whichever schedule minimises
        # window energy (the search objective itself excludes the static
        # term, so we evaluate both schedules post hoc).
        base = bea + beb + STATIC_POWER_W * serial
        conc = min(se.energy + STATIC_POWER_W * se.latency,
                   sched.energy + STATIC_POWER_W * sched.latency)
        energy_reds[(a, b)] = 1.0 - conc / base
    t_solve = time.time() - t_solve

    gm = geomean(list(speedups.values()))
    n_below = sum(1 for v in speedups.values() if v < 1.0)
    top = sorted(speedups.items(), key=lambda kv: -kv[1])[:5]
    bot = sorted(speedups.items(), key=lambda kv: kv[1])[:3]
    avg_ered = sum(energy_reds.values()) / len(energy_reds)

    def _is_complementary(pair) -> bool:
        cpu_bound = ("KAN", "SNN")
        gpu_bound = ("LAVISH", "ViT", "ResNet", "LLaMA", "BitNet", "Hyena")
        a, b = pair
        return ((a.startswith(cpu_bound) and b.startswith(gpu_bound))
                or (b.startswith(cpu_bound) and a.startswith(gpu_bound)))

    checks = {
        "concurrent geomean (%.2fx) > sequential geomean (1.11x)" % gm:
            gm >= 1.15,
        "top-5 pairs include a complementary-affinity pair": any(
            _is_complementary(p) for p, _ in top),
        "few pairs below 1x (got %d/190; paper 2/190)" % n_below:
            n_below <= 10,
        # the energy saving is coupled to the makespan reduction through
        # the static-power term: at our ~1.2x geomean the achievable
        # saving is a few percent; the paper's 48.2% corresponds to 3.42x
        "avg concurrent energy reduction > 0 (got %.1f%%; paper 48.2%% "
        "at 3.42x speedup)" % (100 * avg_ered): avg_ered > 0.0,
    }
    gran = ("full operator resolution" if max_segments is None
            else f"<= {max_segments} segments")
    if verbose:
        print(f"== Fig. 8: multi-model concurrent (190 pairs, {gran}) ==")
        print(f"setup {t_setup:.1f}s, 380 concurrent solves {t_solve:.1f}s")
        print(f"geomean speedup: {gm:.2f}x  (paper: 3.42x — see deviation "
              "note in module docstring)")
        print(f"range: {min(speedups.values()):.2f}x – "
              f"{max(speedups.values()):.2f}x  (paper: 0.86–22.4x)")
        print(f"pairs < 1x: {n_below}/190 (paper: 2/190)")
        print(f"avg energy reduction: {100*avg_ered:.1f}% (paper: 48.2%)")
        print("top pairs:")
        for (a, b), v in top:
            print(f"  {a} + {b}: {v:.2f}x")
        print("bottom pairs:")
        for (a, b), v in bot:
            print(f"  {a} + {b}: {v:.2f}x")
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    return {"geomean": gm, "n_below": n_below, "avg_energy_red": avg_ered,
            "top": [(f"{a}+{b}", v) for (a, b), v in top], "checks": checks,
            "granularity": gran, "setup_s": t_setup, "solve_s": t_solve}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--max-segments", type=int, default=None,
                    help="opt back into the seed's <=N-segment coarsening "
                         "(default: full operator resolution)")
    args = ap.parse_args()
    run(max_segments=args.max_segments)
