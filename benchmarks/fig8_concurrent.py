"""Fig. 8: multi-model concurrent orchestration over the 19 model-precision
configurations, vs homogeneous serial execution (each model sequentially on
its own best single PU).

The sweep drives the ``Orchestrator`` front door: every zoo config
registers once (one dense ``Workload`` per model for the whole sweep),
pairs/combos are ``plan``ed per objective, and each workload tuple's
latency- and energy-objective solves share the orchestrator's
objective-independent cache pool (``PairCostCache``/group edges built
once per pair).  Plans are bitwise-identical to the direct
``solve_concurrent*`` calls the sweep used to hand-assemble.

Pair mode (default, the paper's experiment): all 190 unique pairs.
Same-model pairs use the aligned solver (``mode="aligned"``); mixed
pairs the joint (i, j) search (paper §3.2.2).  The sweep runs at **full
operator resolution**: the dense-table A* joint solver walks the optimal
corridor of the progress grid directly, so even the pi0.5 x Hyena pair
(4,334 x 504 ops) solves in ~150 ms.  The seed's mandatory <= 48-segment
coarsening (``common.segment_table``) is retired as an approximation and
kept only as an opt-in fallback (``max_segments=``/``--max-segments``)
for comparison runs.

M-model mode (``--n-models 3`` / ``4``): sweeps **all** combinations of
M distinct zoo configs through M-ary ``plan`` (969 triples / 3876 quads
— ``--limit`` opts into deterministic sampling for quick runs) — the
vectorized M-dimensional grid sweep solves every combo whose progress
grid fits the exact-solve ceiling, the rolling-horizon merge
co-schedules the rest window by window (the per-combo solver route is
reported, never silently).  The mode also co-schedules M small
*executable* payload models and ``execute``s them for real on the
multi-lane ``ScheduleExecutor``, verifying orchestrated outputs bitwise
against isolated execution.

Claims validated (structural): concurrent geomean clearly exceeds the
sequential geomean; complementary-affinity pairs (CPU-bound KAN/SNN x
GPU-bound LAVISH/ViT) rank near the top; very few pairs fall below 1x;
energy-optimal co-scheduling gives a positive average energy reduction.

Deviation note (EXPERIMENTS.md §Claims): the paper's absolute 3.42x
geomean (range up to 22.4x) reflects serial-baseline effects on real
silicon (per-PU model reload / cache thrash between alternating models)
that a cost-model reproduction has no basis to assume; the analytical
upper bound for co-scheduling two equal-length models over idle PUs
without those effects is ~2-3x.  Scheduling *granularity* is no longer
part of the deviation: these numbers are the exact optima of the cost
model at native operator granularity, and full-resolution results are
the reference for subsequent PRs (the coarsened numbers differ by the
documented approximation error of segment merging, not by search error).
The M >= 3 sweep extends the formulation beyond the paper (which stops
at pairs); its speedups are reported against the same serial
best-single-PU baseline and are capped by the same analysis (at most
~K x for K PUs, minus contention).
"""
from __future__ import annotations

import itertools
import time

import numpy as np

from repro.core import (ContentionModel, EDGE_PUS, EdgeSoCCostModel,
                        FusedOp, OpGraph, Orchestrator, ScheduleExecutor,
                        Workload)
from repro.core.costmodel import STATIC_POWER_W
from repro.core.paperzoo import zoo

from .common import best_single, geomean, segment_table


def _setup(max_segments: int | None, cm: ContentionModel
           ) -> tuple[Orchestrator, dict, list[str], float]:
    """One Orchestrator session for the whole sweep: every zoo config
    registers once (dense workload memoized per model), and the serial
    baselines come off the registered full-resolution workloads.  The
    Fig. 8 baseline is "each model runs sequentially on its best single
    PU" — the energy claim compares against the energy of THAT execution
    (not against an energy-best serial run), consistent with the paper."""
    model = EdgeSoCCostModel()
    z = zoo()
    t_setup = time.time()
    orch = Orchestrator(model, EDGE_PUS, cm)
    seg = {}
    for name, g in z.items():
        full_table = model.build_table(g)
        if max_segments is None:
            h = orch.register(g, table=full_table)
            full_wl = orch.workload(h)
        else:
            # coarsened pair solves; baselines still at full resolution
            chain, table = segment_table(g, full_table, max_segments)
            h = orch.register(
                [FusedOp(name=f"seg{i}", kind="other", out_shape=(1,))
                 for i in range(len(chain))], table=table)
            full_wl = Workload.build(list(range(len(g))), full_table,
                                     EDGE_PUS, ops=g.ops)
        bpu, bl, _ = best_single(full_wl.chain, g.ops, full_table,
                                 workload=full_wl)
        _, be = full_wl.single_pu(bpu)
        seg[name] = (h, bl, be)
    return orch, seg, list(z), time.time() - t_setup


def run(verbose: bool = True, max_segments: int | None = None) -> dict:
    """Run the 190-pair sweep through the orchestrator front door.

    ``max_segments=None`` (default) schedules at full operator
    resolution; an integer opts back into the seed's segment coarsening.
    """
    cm = ContentionModel()
    orch, seg, names, t_setup = _setup(max_segments, cm)

    pairs = list(itertools.combinations_with_replacement(names, 2))
    assert len(pairs) == 190, len(pairs)
    speedups = {}
    energy_reds = {}
    t_solve = time.time()
    for a, b in pairs:
        ha, bla, bea = seg[a]
        hb, blb, beb = seg[b]
        serial = bla + blb
        # latency- and energy-objective plans of one pair share the
        # orchestrator's objective-independent cache pool, so the 4-D
        # pair-cost reductions are built once per pair
        mode = "aligned" if a == b else "concurrent"
        sched = orch.plan((ha, hb), mode=mode).schedule
        speedups[(a, b)] = serial / sched.latency
        se = orch.plan((ha, hb), objective="energy", mode=mode).schedule
        # total window energy = active op energy + package static power
        # over the window: shortening the makespan saves static energy —
        # the dominant source of the paper's concurrent energy reduction.
        # The energy-aware scheduler picks whichever schedule minimises
        # window energy (the search objective itself excludes the static
        # term, so we evaluate both schedules post hoc).
        base = bea + beb + STATIC_POWER_W * serial
        conc = min(se.energy + STATIC_POWER_W * se.latency,
                   sched.energy + STATIC_POWER_W * sched.latency)
        energy_reds[(a, b)] = 1.0 - conc / base
    t_solve = time.time() - t_solve

    gm = geomean(list(speedups.values()))
    n_below = sum(1 for v in speedups.values() if v < 1.0)
    top = sorted(speedups.items(), key=lambda kv: -kv[1])[:5]
    bot = sorted(speedups.items(), key=lambda kv: kv[1])[:3]
    avg_ered = sum(energy_reds.values()) / len(energy_reds)

    def _is_complementary(pair) -> bool:
        cpu_bound = ("KAN", "SNN")
        gpu_bound = ("LAVISH", "ViT", "ResNet", "LLaMA", "BitNet", "Hyena")
        a, b = pair
        return ((a.startswith(cpu_bound) and b.startswith(gpu_bound))
                or (b.startswith(cpu_bound) and a.startswith(gpu_bound)))

    checks = {
        "concurrent geomean (%.2fx) > sequential geomean (1.11x)" % gm:
            gm >= 1.15,
        "top-5 pairs include a complementary-affinity pair": any(
            _is_complementary(p) for p, _ in top),
        "few pairs below 1x (got %d/190; paper 2/190)" % n_below:
            n_below <= 10,
        # the energy saving is coupled to the makespan reduction through
        # the static-power term: at our ~1.2x geomean the achievable
        # saving is a few percent; the paper's 48.2% corresponds to 3.42x
        "avg concurrent energy reduction > 0 (got %.1f%%; paper 48.2%% "
        "at 3.42x speedup)" % (100 * avg_ered): avg_ered > 0.0,
    }
    gran = ("full operator resolution" if max_segments is None
            else f"<= {max_segments} segments")
    if verbose:
        print(f"== Fig. 8: multi-model concurrent (190 pairs, {gran}) ==")
        print(f"setup {t_setup:.1f}s, 380 concurrent solves {t_solve:.1f}s")
        print(f"geomean speedup: {gm:.2f}x  (paper: 3.42x — see deviation "
              "note in module docstring)")
        print(f"range: {min(speedups.values()):.2f}x – "
              f"{max(speedups.values()):.2f}x  (paper: 0.86–22.4x)")
        print(f"pairs < 1x: {n_below}/190 (paper: 2/190)")
        print(f"avg energy reduction: {100*avg_ered:.1f}% (paper: 48.2%)")
        print("top pairs:")
        for (a, b), v in top:
            print(f"  {a} + {b}: {v:.2f}x")
        print("bottom pairs:")
        for (a, b), v in bot:
            print(f"  {a} + {b}: {v:.2f}x")
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    return {"geomean": gm, "n_below": n_below, "avg_energy_red": avg_ered,
            "top": [(f"{a}+{b}", v) for (a, b), v in top], "checks": checks,
            "granularity": gran, "setup_s": t_setup, "solve_s": t_solve}


# ---------------------------------------------------------------------------
# M-model mode (beyond-paper: triples/quads of zoo configs)
# ---------------------------------------------------------------------------


def _payload_models(m: int):
    """M small *executable* models (NumPy payloads) for lane verification."""
    rng = np.random.default_rng(0)
    graphs, inputs = [], []
    for r in range(m):
        ops = []
        if r % 2 == 0:
            w = [rng.standard_normal((64, 64)) / 8.0 for _ in range(5)]
            for i in range(5):
                ops.append(FusedOp(
                    name=f"m{r}.mm{i}", kind="matmul",
                    in_shapes=((1, 64, 64), (64, 64)), out_shape=(1, 64, 64),
                    fn=(lambda wi: lambda a: np.maximum(a @ wi, 0.0))(w[i])))
        else:
            for i in range(6):
                ops.append(FusedOp(
                    name=f"m{r}.cs{i}", kind="cumsum",
                    in_shapes=((1, 64, 64),), out_shape=(1, 64, 64),
                    fn=lambda a: np.cumsum(a, axis=1) / a.shape[1]))
        graphs.append(OpGraph(ops))
        inputs.append({0: (rng.standard_normal((1, 64, 64)),)})
    return graphs, inputs


def _verify_executor(m: int, cm: ContentionModel) -> bool:
    """Register M executable models, ``plan`` them concurrently, and
    ``execute`` across the PU lanes — each model's outputs must match
    isolated execution bitwise."""
    graphs, inputs = _payload_models(m)
    orch = Orchestrator(EdgeSoCCostModel(), EDGE_PUS, cm)
    plan = orch.plan([orch.register(g) for g in graphs])
    conc = orch.execute(plan, inputs)
    for g, x, got in zip(graphs, inputs, conc):
        mono = orch.executor.run_monolithic(g, x)
        if not ScheduleExecutor.outputs_close(mono, got):
            return False
    return True


def run_multi(verbose: bool = True, n_models: int = 3,
              limit: int | None = None, seed: int = 0,
              max_segments: int | None = None) -> dict:
    """Sweep M-model combinations of distinct zoo configs.

    The **full** combination sweep is the default (the vectorized grid
    sweep + rolling-horizon merge made it affordable); ``limit`` opts
    into sampling (deterministic ``seed``) for quick/CI runs.  Per-combo
    the solver route (exact grid vs rolling-horizon vs pairwise) is
    recorded — nothing is silently approximated.
    """
    cm = ContentionModel()
    orch, seg, names, t_setup = _setup(max_segments, cm)
    combos = list(itertools.combinations(names, n_models))
    n_total = len(combos)
    if limit is not None and limit < n_total:
        rng = np.random.default_rng(seed)
        idx = rng.choice(n_total, size=limit, replace=False)
        combos = [combos[i] for i in sorted(idx)]

    speedups = {}
    energy_reds = {}
    modes: dict[str, int] = {}
    t_solve = time.time()
    for combo in combos:
        hs = tuple(seg[n][0] for n in combo)
        serial = sum(seg[n][1] for n in combo)
        # the combo's latency + energy plans share the orchestrator's
        # per-workload-tuple cache pool (group edges / pair caches built
        # by the latency solve are reused by the energy solve)
        sched = orch.plan(hs).schedule
        se = orch.plan(hs, objective="energy").schedule
        modes[sched.mode] = modes.get(sched.mode, 0) + 1
        speedups[combo] = serial / sched.latency
        base = (sum(seg[n][2] for n in combo) + STATIC_POWER_W * serial)
        conc = min(se.energy + STATIC_POWER_W * se.latency,
                   sched.energy + STATIC_POWER_W * sched.latency)
        energy_reds[combo] = 1.0 - conc / base
    t_solve = time.time() - t_solve

    exec_ok = _verify_executor(n_models, cm)
    gm = geomean(list(speedups.values()))
    n_below = sum(1 for v in speedups.values() if v < 1.0)
    avg_ered = sum(energy_reds.values()) / len(energy_reds)
    top = sorted(speedups.items(), key=lambda kv: -kv[1])[:5]
    checks = {
        "M=%d concurrent geomean (%.2fx) > 1x" % (n_models, gm): gm > 1.0,
        "no combo below 0.95x (got %d < 1x)" % n_below:
            all(v >= 0.95 for v in speedups.values()),
        "avg energy reduction > 0 (got %.1f%%)" % (100 * avg_ered):
            avg_ered > 0.0,
        "executor: M-model orchestrated outputs == isolated": exec_ok,
    }
    gran = ("full operator resolution" if max_segments is None
            else f"<= {max_segments} segments")
    if verbose:
        print(f"== Fig. 8 extension: {n_models}-model concurrent "
              f"({len(combos)}/{n_total} combos, {gran}) ==")
        print(f"setup {t_setup:.1f}s, {2*len(combos)} solves {t_solve:.1f}s"
              f"  (solver routes: {modes})")
        print(f"geomean speedup: {gm:.2f}x over serial best-single-PU")
        print(f"avg energy reduction: {100*avg_ered:.1f}%")
        print("top combos:")
        for combo, v in top:
            print(f"  {' + '.join(combo)}: {v:.2f}x")
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    return {"n_models": n_models, "n_combos": len(combos),
            "n_combos_total": n_total, "geomean": gm, "n_below": n_below,
            "avg_energy_red": avg_ered, "solver_modes": modes,
            "top": [(" + ".join(c), v) for c, v in top], "checks": checks,
            "granularity": gran, "setup_s": t_setup, "solve_s": t_solve}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--max-segments", type=int, default=None,
                    help="opt back into the seed's <=N-segment coarsening "
                         "(default: full operator resolution)")
    ap.add_argument("--n-models", type=int, default=2,
                    help="models co-scheduled per combination (2 = the "
                         "paper's 190-pair sweep; >=3 = M-model extension)")
    ap.add_argument("--limit", type=int, default=0,
                    help="opt-in: sample at most N combinations in "
                         "M-model mode (default 0 = full sweep, including "
                         "the unsampled 3876-quad sweep at --n-models 4)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling seed for --limit")
    args = ap.parse_args()
    if args.n_models <= 2:
        out = run(max_segments=args.max_segments)
    else:
        out = run_multi(n_models=args.n_models,
                        limit=args.limit or None, seed=args.seed,
                        max_segments=args.max_segments)
    raise SystemExit(0 if all(out["checks"].values()) else 1)
