"""Shared helpers for the per-table benchmark modules."""
from __future__ import annotations

import math
import platform
import time
from typing import Mapping, Sequence

from repro.core import (CostTable, EdgeSoCCostModel, EDGE_PUS, Orchestrator,
                        Workload, single_pu_cost, solve_sequential)
from repro.core.costmodel import CostEntry
from repro.core.op import FusedOp, OpGraph

PUS = ("CPU", "GPU", "NPU")


def geomean(xs: Sequence[float]) -> float:
    if not xs:
        raise ValueError("geomean of an empty sequence")
    bad = [x for x in xs if x <= 0]
    if bad:
        raise ValueError(
            f"geomean requires positive values; got {len(bad)} non-positive "
            f"entries (e.g. {bad[0]!r})")
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def best_single(chain, ops, table, pus=EDGE_PUS, objective: str = "latency",
                workload: Workload | None = None):
    """(best_pu, value, per_pu dict) of monolithic execution — a thin
    wrapper over ``Workload.best_solo`` that adds per-PU blocker detail
    to the infeasibility error."""
    wl = workload if workload is not None else Workload.build(
        chain, table, pus, ops=ops)
    try:
        return wl.best_solo(objective)
    except ValueError:
        blockers = {
            pu: [f"op {oi} ({ops[oi].name})" for oi in chain
                 if not table.supported(oi, pu)][:3]
            for pu in table.pus}
        raise ValueError(
            "no single PU supports every op of the chain "
            f"(len={len(chain)}); first unsupported ops per PU: {blockers}")


def sequential_report(graph: OpGraph, model: EdgeSoCCostModel | None = None):
    """One Table-2 row: single-PU latencies + BIDENT-lat + BIDENT-energy.

    Runs through the ``Orchestrator`` front door: one ``register`` (the
    single dense ingestion, shared by the baselines and both solves),
    then a latency and an energy ``plan`` — bitwise what the direct
    ``solve_sequential`` calls returned."""
    orch = Orchestrator(model or EdgeSoCCostModel(), EDGE_PUS)
    h = orch.register(graph)
    wl = orch.workload(h)
    table, chain = wl.table, wl.chain
    b, bl, lat = best_single(chain, graph.ops, table, workload=wl)
    sched_l = orch.plan(h, mode="sequential").schedule
    sched_e = orch.plan(h, objective="energy", mode="sequential").schedule
    _, be, _ = best_single(chain, graph.ops, table, objective="energy",
                           workload=wl)
    return {
        "table": table, "chain": chain, "best": b,
        "single_lat": lat, "best_lat": bl, "best_energy": be,
        "bident_lat": sched_l.latency, "bident_lat_energy": sched_l.energy,
        "bident_energy": sched_e.energy, "bident_energy_lat": sched_e.latency,
        "speedup": bl / sched_l.latency,
        "energy_red_latopt": 1.0 - sched_l.energy / be,
        "energy_red_engopt": 1.0 - sched_e.energy / be,
        "sched_l": sched_l, "sched_e": sched_e,
    }


# ---------------------------------------------------------------------------
# segment coarsening for the 190-pair concurrent sweep
# ---------------------------------------------------------------------------


def segment_table(graph: OpGraph, table: CostTable,
                  max_segments: int = 48) -> tuple[list[int], CostTable]:
    """Collapse a long op chain into <= max_segments super-ops.

    Consecutive ops merge into one segment whose per-PU cost is the sum of
    member costs (intra-segment transitions are zero: one PU per segment).
    A segment supports a PU iff every member does — so e.g. KAN segments
    stay NPU-less.

    Historical note: this coarsening was *required* by the seed's pure-
    Python joint (i, j) Dijkstra to keep the 190-pair sweep tractable.
    Since the dense-table A* joint solver landed, ``fig8_concurrent`` runs
    at full operator resolution by default and this helper is an opt-in
    fallback (``--max-segments``) kept for comparison runs and for
    scheduler micro-benchmarks at fixed granularity.
    """
    chain = graph.topo_order()
    n = len(chain)
    seg_len = max(1, -(-n // max_segments))
    segments: list[list[int]] = [chain[i:i + seg_len]
                                 for i in range(0, n, seg_len)]
    out = CostTable(list(table.pus))
    for si, seg in enumerate(segments):
        sup = set(table.pus)
        for oi in seg:
            sup &= set(table.supported_pus(oi))
        for pu in sup:
            w = sum(table.require(oi, pu).w for oi in seg)
            e = sum(table.require(oi, pu).energy for oi in seg)
            first = table.require(seg[0], pu)
            last = table.require(seg[-1], pu)
            out.set(si, pu, CostEntry(
                kernel=w, dispatch=0.0, h2d=first.h2d, d2h=last.d2h,
                power=(e / w if w > 0 else first.power)))
    return list(range(len(segments))), out


def env_meta() -> dict:
    """Environment provenance for every ``BENCH_*.json``: numbers are
    meaningless without knowing what produced them.  Records python /
    jax / jaxlib versions, the backend platform and device kinds, and
    the registered target names; degrades gracefully (``jax: null``)
    when jax is absent."""
    meta = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "jax": None, "jaxlib": None, "backend": None, "devices": [],
        "targets": [],
    }
    try:
        import jax
        import jaxlib
        meta["jax"] = jax.__version__
        meta["jaxlib"] = jaxlib.__version__
        meta["backend"] = jax.default_backend()
        meta["devices"] = [
            {"id": d.id, "platform": d.platform,
             "kind": getattr(d, "device_kind", "?")}
            for d in jax.devices()]
    except Exception as e:  # pragma: no cover - jax-less env
        meta["jax_error"] = f"{type(e).__name__}: {e}"
    try:
        from repro.core.backends import default_registry
        meta["targets"] = default_registry().names()
    except Exception as e:  # pragma: no cover
        meta["targets_error"] = f"{type(e).__name__}: {e}"
    return meta


class Timer:
    def __init__(self, label: str):
        self.label = label

    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
