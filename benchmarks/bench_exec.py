"""Executor micro-benchmark: per-op dispatch overhead, interpreted vs
compiled lane programs.

After PRs 1-4 made *planning* ms-scale, per-op execution overhead (one
Python closure dispatch + one ``threading.Event`` wait/set per op) became
the dominant runtime cost — exactly the overhead the paper says the
execution orchestrator must not add.  This benchmark pins it down across
the fig8 zoo chains plus an M=3 concurrent run:

* **interpreted** — ``Orchestrator.execute(..., compile=False)``, the
  per-op event-synced oracle;
* **compiled cold** — first ``execute`` through the compiled path
  (segment partitioning + per-segment ``jax.jit`` + bitwise verify);
* **compiled warm** — repeat ``execute`` hitting the orchestrator's
  program cache (the serving steady state).

Every op carries a tiny uniform-shape JAX payload, so wall-clock divided
by op count isolates dispatch/synchronisation overhead rather than
kernel time.  Checks (recorded in ``BENCH_exec.json``): warm compiled
per-op overhead must be >= 5x lower than interpreted (geomean), and
compiled outputs must be bitwise identical to ``run_monolithic`` on
every model exercised — the bitwise gate holds even under ``--smoke``.
"""
from __future__ import annotations

import functools
import json
import operator
import time

import jax.numpy as jnp

from repro.core import (EDGE_PUS, EdgeSoCCostModel, FusedOp, OpGraph,
                        Orchestrator, results_bitwise_equal)
from repro.core.paperzoo import zoo

from .common import env_meta, geomean

ZOO_MODELS = ["ResNet-50 FP16", "BitNet FP16", "LLaMA-7B(1L) FP16",
              "Mamba-370M FP16", "ViT-B/16 FP16"]
SMOKE_MODELS = ["BitNet FP16", "LLaMA-7B(1L) FP16"]
DIM = 8                      # payload shape (DIM, DIM) f32 for every op
OVERHEAD_TARGET = 5.0        # warm compiled must beat interpreted by this


def attach_payloads(g: OpGraph) -> dict[int, tuple]:
    """Give every op a tiny uniform-shape jittable payload.

    Payload cost is deliberately negligible and identical across ops so
    that execution wall-clock measures the *dispatch* path, not kernels.
    Roots consume one external input; interior ops fold their
    predecessors (matching the executor's ext-then-preds arg order).
    Every payload ends in ``tanh`` so no ``mul`` result ever feeds an
    ``add`` inside a fused segment — XLA would contract that pair into an
    FMA, which changes rounding vs eager execution and would (correctly)
    trip the lane program's bitwise probe into the Python fallback.
    Returns the external-inputs mapping for the graph's root ops.
    """
    x = jnp.linspace(0.0, 1.0, DIM * DIM,
                     dtype=jnp.float32).reshape(DIM, DIM)
    inputs: dict[int, tuple] = {}
    for i, op in enumerate(g.ops):
        c = jnp.float32(1.0 + 0.01 * (i % 7))
        if g.pred[i]:
            op.fn = (lambda c: lambda *a: jnp.tanh(
                functools.reduce(operator.add, a) * c))(c)
        else:
            op.fn = (lambda c: lambda v: jnp.tanh(v * c))(c)
            inputs[i] = (x,)
    return inputs


def _concurrent_payload_models(n_ops: int = 24):
    """Three affinity-distinct chains with jittable payloads for the
    M=3 concurrent run (GEMM- / scan- / conv-class kinds, so the solver
    spreads them across lanes)."""
    graphs, inputs = [], []
    kinds = ("matmul", "cumsum", "conv2d")
    x = jnp.linspace(-1.0, 1.0, DIM * DIM,
                     dtype=jnp.float32).reshape(DIM, DIM)
    for r, kind in enumerate(kinds):
        ops = []
        for i in range(n_ops):
            c = jnp.float32(1.0 + 0.005 * ((r + i) % 11))
            if kind == "matmul":
                fn = (lambda c: lambda a: jnp.tanh(a * c))(c)
            elif kind == "cumsum":
                fn = (lambda c: lambda a:
                      jnp.cumsum(jnp.tanh(a), axis=0) * (c / DIM))(c)
            else:
                fn = (lambda c: lambda a: jnp.tanh(jnp.abs(a) * c))(c)
            ops.append(FusedOp(name=f"m{r}.{kind}{i}", kind=kind,
                               in_shapes=((DIM, DIM),), out_shape=(DIM, DIM),
                               fn=fn))
        graphs.append(OpGraph(ops))
        inputs.append({0: (x,)})
    return graphs, inputs


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _bench_plan(orch: Orchestrator, plan, graphs, inputs, n_ops: int,
                repeats: int, warm_repeats: int) -> dict:
    """Time one plan both ways + verify bitwise identity vs monolithic."""
    single = plan.kind in ("sequential", "parallel")
    orch.execute(plan, inputs, compile=False)     # warm jax's eager caches
    interp_s = _best_of(
        lambda: orch.execute(plan, inputs, compile=False), repeats)
    t0 = time.perf_counter()
    compiled_out = orch.execute(plan, inputs)     # cold: partition + jit
    cold_s = time.perf_counter() - t0
    warm_s = _best_of(lambda: orch.execute(plan, inputs), warm_repeats)

    outs = [compiled_out] if single else compiled_out
    ins = [inputs] if single else inputs
    bitwise = all(
        results_bitwise_equal(orch.executor.run_monolithic(g, i), o)
        for g, i, o in zip(graphs, ins, outs))
    prog = orch.program_for(plan, inputs)
    return {
        "n_ops": n_ops,
        "interp_ms": 1e3 * interp_s,
        "cold_compile_ms": 1e3 * cold_s,
        "warm_ms": 1e3 * warm_s,
        "per_op_interp_us": 1e6 * interp_s / n_ops,
        "per_op_warm_us": 1e6 * warm_s / n_ops,
        "overhead_reduction": interp_s / warm_s,
        "bitwise_vs_monolithic": bitwise,
        "program": prog.stats,
    }


def run(verbose: bool = True, smoke: bool = False,
        out_path: str | None = "BENCH_exec.json") -> dict:
    model = EdgeSoCCostModel()
    z = zoo()
    names = SMOKE_MODELS if smoke else ZOO_MODELS
    repeats = 1 if smoke else 3
    warm_repeats = 3 if smoke else 10

    out: dict = {"smoke": smoke, "models": {}, "concurrent_m": {}}
    for name in names:
        g = z[name]
        inputs = attach_payloads(g)
        orch = Orchestrator(model, EDGE_PUS)
        plan = orch.plan(orch.register(g))
        row = _bench_plan(orch, plan, [g], inputs, len(g),
                          repeats, warm_repeats)
        row["plan_kind"] = plan.kind
        out["models"][name] = row

    graphs, inputs = _concurrent_payload_models(12 if smoke else 24)
    orch = Orchestrator(model, EDGE_PUS)
    cplan = orch.plan([orch.register(g) for g in graphs])
    row = _bench_plan(orch, cplan, graphs, inputs,
                      sum(len(g) for g in graphs), repeats, warm_repeats)
    row["mode"] = cplan.schedule.mode
    out["concurrent_m"][f"M=3 x {len(graphs[0])} ops"] = row

    rows = list(out["models"].values()) + list(out["concurrent_m"].values())
    reduction = geomean([r["overhead_reduction"] for r in rows])
    bitwise_ok = all(r["bitwise_vs_monolithic"] for r in rows)
    out["overhead_reduction_geomean"] = reduction
    out["checks"] = {
        "warm compiled per-op overhead >= %.0fx lower than interpreted "
        "(geomean %.1fx)" % (OVERHEAD_TARGET, reduction):
            reduction >= OVERHEAD_TARGET,
        "compiled outputs bitwise-identical to run_monolithic on every "
        "model exercised": bitwise_ok,
    }

    if verbose:
        print(f"== executor micro-benchmark ({'smoke' if smoke else 'full'}) ==")
        for name, r in {**out["models"], **out["concurrent_m"]}.items():
            p = r["program"]
            print(f"  {name:24s} n={r['n_ops']:5d}  "
                  f"interp {r['per_op_interp_us']:7.1f}us/op  "
                  f"warm {r['per_op_warm_us']:7.1f}us/op  "
                  f"({r['overhead_reduction']:.1f}x)  "
                  f"cold {r['cold_compile_ms']:8.1f}ms  "
                  f"[{p['n_segments']} seg, {p['n_jitted']} jit, "
                  f"{p['n_python']} py]  "
                  f"bitwise={'OK' if r['bitwise_vs_monolithic'] else 'FAIL'}")
        for c, ok in out["checks"].items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")

    if out_path:
        out["meta"] = env_meta()
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
        if verbose:
            print(f"wrote {out_path}")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset (CI)")
    ap.add_argument("--out", default=None,
                    help="output JSON path ('' to skip writing; default "
                         "BENCH_exec.json, or BENCH_exec.smoke.json under "
                         "--smoke so the tracked full-run trajectory is "
                         "never clobbered by a smoke run)")
    args = ap.parse_args()
    out_path = args.out
    if out_path is None:
        out_path = "BENCH_exec.smoke.json" if args.smoke else "BENCH_exec.json"
    out = run(smoke=args.smoke, out_path=out_path or None)
    # the bitwise-identity check gates even --smoke (it is a correctness
    # claim, not a timing claim); wall-clock ratio checks are
    # informational under --smoke (single-repeat CI timings are noisy)
    bitwise_ok = all(ok for c, ok in out["checks"].items() if "bitwise" in c)
    raise SystemExit(0 if (bitwise_ok and (args.smoke
                                           or all(out["checks"].values())))
                     else 1)
