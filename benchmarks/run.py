"""Benchmark harness: one module per paper table/figure + the TPU-mode
beyond-paper table.  ``python -m benchmarks.run`` executes everything and
summarises claim validation.
"""
from __future__ import annotations

import sys
import time

from . import (bench_dag, bench_sched, fig2_op_affinity, fig3_matmul_sweep,
               fig4_parallel_pairs, fig6_energy, fig8_concurrent,
               table2_sequential, table3_parallel, tpu_autoshard)

class _fig8_multi:
    """Harness shim: the beyond-paper M-model extension of Fig. 8."""

    @staticmethod
    def run(verbose: bool = True) -> dict:
        return fig8_concurrent.run_multi(verbose=verbose, n_models=3,
                                         limit=15)


MODULES = [
    ("Fig. 2 operator affinity", fig2_op_affinity),
    ("Fig. 3 MatMul size sweep", fig3_matmul_sweep),
    ("Fig. 4 parallel op pairs", fig4_parallel_pairs),
    ("Table 2 sequential orchestration", table2_sequential),
    ("Fig. 6 energy objectives", fig6_energy),
    ("Table 3 intra-model parallel", table3_parallel),
    ("Fig. 8 multi-model concurrent (190 pairs, full resolution)",
     fig8_concurrent),
    ("Fig. 8 extension: 3-model concurrent sweep", _fig8_multi),
    ("Scheduler micro-benchmark (BENCH_sched.json)", bench_sched),
    ("DAG-route benchmark (VLA intra-model parallelism)", bench_dag),
    ("TPU autoshard (beyond-paper)", tpu_autoshard),
]


def main() -> int:
    all_checks: dict[str, dict[str, bool]] = {}
    for label, mod in MODULES:
        print("\n" + "=" * 72)
        print(label)
        print("=" * 72)
        t0 = time.time()
        out = mod.run(verbose=True)
        all_checks[label] = out.get("checks", {})
        print(f"[{label}: {time.time()-t0:.1f}s]")

    print("\n" + "=" * 72)
    print("CLAIM VALIDATION SUMMARY")
    print("=" * 72)
    n_pass = n_fail = 0
    for label, checks in all_checks.items():
        for c, ok in checks.items():
            n_pass += ok
            n_fail += not ok
            if not ok:
                print(f"FAIL  [{label}] {c}")
    print(f"{n_pass} checks passed, {n_fail} failed "
          f"(across {len(all_checks)} benchmark modules)")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
