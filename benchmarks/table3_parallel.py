"""Table 3: intra-model parallel orchestration (FP16 configs + pi0.5).

Phase/branch partitioning + per-branch Dijkstra + contention-adjusted
makespans.  Claims validated: parallel >= sequential everywhere (the
scheduler keeps the serial fallback per phase); gains concentrate in
branchy models (ViT heads / LAVISH dual towers / pi0.5 stages /
Hyena filter branches); BitNet (a single chain, 0 concurrent phases)
gains nothing.
"""
from __future__ import annotations

from repro.core import EDGE_PUS, EdgeSoCCostModel, solve_parallel
from repro.core.paperzoo import zoo

from .common import best_single, geomean

FP16_SET = ("ResNet-50 FP16", "ViT-B/16 FP16", "LLaMA-7B(1L) FP16",
            "BitNet FP16", "Mamba-370M FP16", "Hyena FP16", "KAN FP16",
            "SNN-VGG9 FP16", "LAVISH FP16", "pi0.5")


def run(verbose: bool = True) -> dict:
    model = EdgeSoCCostModel()
    z = zoo()
    rows = {}
    for name in FP16_SET:
        g = z[name]
        table = model.build_table(g)
        chain = g.topo_order()
        _, bl, _ = best_single(chain, g.ops, table)
        from repro.core import solve_sequential
        seq = solve_sequential(chain, g.ops, table, EDGE_PUS)
        par = solve_parallel(g, table, EDGE_PUS)
        rows[name] = {
            "par_speedup": bl / par.latency,
            "seq_speedup": bl / seq.latency,
            "par_gain": seq.latency / par.latency - 1.0,
            "conc_phases": par.n_concurrent_phases,
        }
    checks = {
        "parallel >= sequential for every model": all(
            r["par_speedup"] >= r["seq_speedup"] - 1e-9 for r in rows.values()),
        "BitNet: 0 concurrent phases, no parallel gain":
            rows["BitNet FP16"]["conc_phases"] == 0
            and rows["BitNet FP16"]["par_gain"] < 1e-9,
        "branchy models gain >= 5% (ViT/LAVISH/pi0.5/Hyena)": all(
            rows[k]["par_gain"] >= 0.05
            for k in ("ViT-B/16 FP16", "LAVISH FP16", "pi0.5", "Hyena FP16")),
        "max parallel speedup >= 1.3x (paper: 1.60x)": max(
            r["par_speedup"] for r in rows.values()) >= 1.3,
    }
    if verbose:
        print("== Table 3: intra-model parallel orchestration ==")
        print(f"{'model':18s} {'par spdup':>9s} {'gain':>6s} {'phases':>7s}")
        for name, r in rows.items():
            print(f"{name:18s} {r['par_speedup']:8.2f}x "
                  f"{100*r['par_gain']:+5.0f}% {r['conc_phases']:7d}")
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    run()
