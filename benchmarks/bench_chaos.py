"""Chaos-serving benchmark: availability of the degraded-mode serving
loop under scripted fault traces.

Drives ``ServingEngine(execution="real")`` through four chaos scenarios
(each a replayable :class:`ChaosTrace`, run under a hard SIGALRM
timeout so a wedged loop fails the gate instead of hanging CI):

* **transient_storm** — a burst of transient faults early in the run;
  the per-op retry loop must absorb them (every request completes).
* **straggler** — one lane injected with persistent per-op delay; the
  health monitor must collect drift observations on that lane while the
  run stays bitwise-correct.
* **stall** — one lane stalls far past the watchdog budget; the loop
  must respond (window retries, a breaker open, or typed sheds) and
  drain — never hang.
* **pu_lost_return** — a lane dies mid-run and returns later; the
  breaker must open, the active set recover fleet-wide (recovery
  latency recorded), and a half-open probe re-admit the lane after its
  scripted return.

Gates (enforced under ``--smoke`` too — these are the acceptance
criteria of degraded-mode serving, not informational timings):
every scenario drains with ``completed + shed == n`` and **zero
bitwise failures** (completed ⇒ bitwise-identical to a fault-free solo
run; otherwise a typed shed); the loss scenario records a breaker open,
>= 1 fleet-wide recovery, and a probe re-admission of the returned
lane.  Results merge into ``BENCH_serve.json`` under ``"chaos"``.
"""
from __future__ import annotations

import contextlib
import json
import os
import signal

import numpy as np

from repro.core import (ArrivalTrace, ChaosEvent, ChaosTrace,
                        EdgeSoCCostModel, ExecutionPolicy, FusedOp,
                        HealthPolicy, Orchestrator, ServingEngine,
                        chain_graph)

from .common import env_meta

DIM = 8
SCENARIO_TIMEOUT_S = 120.0     # hard wall-clock ceiling per scenario


class ScenarioTimeout(Exception):
    pass


@contextlib.contextmanager
def _hard_timeout(seconds: float):
    def handler(signum, frame):
        raise ScenarioTimeout(
            f"scenario exceeded the {seconds}s hard timeout — "
            "a serving path blocked")
    old = signal.signal(signal.SIGALRM, handler)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old)


def _payload(salt: int):
    w = np.random.default_rng(salt).standard_normal(
        (DIM, DIM)).astype(np.float32)
    import jax.numpy as jnp
    wj = jnp.asarray(w)

    def fn(x, w=wj):
        return jnp.tanh(x @ w)
    return fn


def _jax_chain(n: int, salt: int):
    import jax.numpy as jnp
    ops = [FusedOp(name=f"op{salt}_{k}", kind="matmul", flops=1e6,
                   bytes_moved=1e4, fn=_payload(salt * 97 + k))
           for k in range(n)]
    x = jnp.asarray(np.random.default_rng(salt).standard_normal(
        (1, DIM)).astype(np.float32))
    return chain_graph(ops), {0: (x,)}


def _engine(**kw):
    gA, inA = _jax_chain(5, salt=1)
    gB, inB = _jax_chain(4, salt=2)
    orch = Orchestrator(EdgeSoCCostModel())
    kw.setdefault("exec_policy", ExecutionPolicy(timeout=20.0))
    kw.setdefault("health_policy", HealthPolicy(cooldown=0.005))
    kw.setdefault("max_concurrent", 2)
    return ServingEngine(orch, {"A": gA, "B": gB}, execution="real",
                         inputs={"A": inA, "B": inB}, **kw)


def _scenarios(n: int):
    """(name, trace, chaos, engine_kw) per scenario; traces are seeded
    so a failing run replays from the JSON artifacts alone."""
    out = []

    t = ArrivalTrace.poisson(["A", "B"], rate=50.0, n=n, seed=11)
    out.append(("transient_storm", t, ChaosTrace([
        ChaosEvent(time=0.0, kind="transient", count=4),
    ], kind="transient_storm", seed=11), {}))

    t = ArrivalTrace.poisson(["A", "B"], rate=50.0, n=n, seed=12)
    out.append(("straggler", t, ChaosTrace([
        ChaosEvent(time=0.0, kind="straggler", lane="CPU", delay=0.01,
                   count=-1),
    ], kind="straggler", seed=12), {
        "health_policy": HealthPolicy(cooldown=0.005, calibration=4,
                                      rescale_threshold=3.0)}))

    t = ArrivalTrace.poisson(["A", "B"], rate=50.0, n=max(4, n // 2),
                             seed=13)
    out.append(("stall", t, ChaosTrace([
        ChaosEvent(time=0.0, kind="stall", lane="CPU", delay=30.0,
                   count=-1),
    ], kind="stall", seed=13), {
        "exec_policy": ExecutionPolicy(timeout=0.2, min_timeout=0.2,
                                       max_retries=0),
        "max_window_retries": 1}))

    t = ArrivalTrace.poisson(["A", "B"], rate=50.0, n=max(12, n), seed=14)
    out.append(("pu_lost_return", t, ChaosTrace([
        ChaosEvent(time=t.arrivals[3].time, kind="pu_lost", lane="CPU"),
        ChaosEvent(time=t.arrivals[min(8, len(t) - 2)].time,
                   kind="pu_restored", lane="CPU"),
    ], kind="pu_lost_return", seed=14), {}))

    return out


def _row(name: str, rep, timed_out: bool) -> dict:
    return {
        "scenario": name,
        "timed_out": timed_out,
        "n_requests": rep.n_requests if rep else None,
        "completed": rep.completed if rep else 0,
        "shed": rep.shed if rep else 0,
        "shed_reasons": rep.shed_reasons if rep else {},
        "recovered": rep.recovered if rep else 0,
        "retried": rep.retried if rep else 0,
        "recoveries": rep.recoveries if rep else 0,
        "recovery_ms_p50": rep.recovery_ms_p50 if rep else 0.0,
        "recovery_ms_p99": rep.recovery_ms_p99 if rep else 0.0,
        "bitwise_checked": rep.bitwise_checked if rep else 0,
        "bitwise_failures": rep.bitwise_failures if rep else -1,
        "exec_wall_s": rep.exec_wall_s if rep else 0.0,
        "breaker": {k: v for k, v in (rep.breaker or {}).items()
                    if k != "targets"} if rep else {},
        "cache": rep.cache if rep else {},
    }


def run(verbose: bool = True, smoke: bool = False,
        out_path: str | None = None) -> dict:
    n = 8 if smoke else 16
    rows = []
    for name, trace, chaos, kw in _scenarios(n):
        eng = _engine(**kw)
        rep, timed_out = None, False
        try:
            with _hard_timeout(SCENARIO_TIMEOUT_S):
                rep = eng.serve(trace, chaos=chaos)
        except ScenarioTimeout:
            timed_out = True
        rows.append(_row(name, rep, timed_out))

    by = {r["scenario"]: r for r in rows}
    drained = {r["scenario"]:
               (not r["timed_out"]
                and r["completed"] + r["shed"] == r["n_requests"])
               for r in rows}
    plr = by["pu_lost_return"]
    chaosrec = {
        "mode": "smoke" if smoke else "full",
        "scenarios": rows,
        "checks": {
            "every scenario drains under the hard timeout "
            "(completed + shed == n, no hang)": all(drained.values()),
            "zero bitwise failures across all scenarios (completed => "
            "bitwise-identical to fault-free solo run, else typed shed)":
                all(r["bitwise_failures"] == 0 for r in rows),
            "transient storm absorbed in-loop (all requests complete)":
                by["transient_storm"]["shed"] == 0
                and by["transient_storm"]["completed"] == n,
            "stall scenario responds (window retries, breaker open, or "
            "typed sheds) instead of hanging":
                by["stall"]["retried"] >= 1
                or by["stall"]["breaker"].get("opens", 0) >= 1
                or by["stall"]["shed"] >= 1,
            "mid-run PU loss opens the breaker and recovers the active "
            "set fleet-wide (recovery latency recorded)":
                plr["breaker"].get("opens", 0) >= 1
                and plr["recoveries"] >= 1
                and plr["recovery_ms_p50"] > 0.0,
            "returned PU re-admitted via an observed half-open probe":
                plr["breaker"].get("readmits", 0) >= 1,
        },
    }

    if verbose:
        print(f"== chaos-serving benchmark ({chaosrec['mode']}) ==")
        for r in rows:
            b = r["breaker"]
            print(f"  {r['scenario']:16s} {r['completed']}/{r['n_requests']}"
                  f" completed, shed {r['shed']} {r['shed_reasons']}, "
                  f"retried {r['retried']}, recoveries {r['recoveries']} "
                  f"(p50 {r['recovery_ms_p50']:.2f}ms), breaker "
                  f"opens/probes/readmits "
                  f"{b.get('opens', 0)}/{b.get('probes', 0)}/"
                  f"{b.get('readmits', 0)}, bitwise "
                  f"{r['bitwise_checked']} checked "
                  f"{r['bitwise_failures']} failed")
        for c, ok in chaosrec["checks"].items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")

    if out_path:
        # merge into the serving benchmark record rather than clobbering
        merged: dict = {}
        if os.path.exists(out_path):
            with open(out_path) as f:
                merged = json.load(f)
        merged["chaos"] = chaosrec
        merged["meta"] = env_meta()
        with open(out_path, "w") as f:
            json.dump(merged, f, indent=2)
        if verbose:
            print(f"wrote {out_path} (chaos section)")
    return chaosrec


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset (CI)")
    ap.add_argument("--out", default=None,
                    help="output JSON path ('' to skip writing; default "
                         "BENCH_serve.json, or BENCH_serve.smoke.json "
                         "under --smoke so the tracked full-run "
                         "trajectory is never clobbered by a smoke run)")
    args = ap.parse_args()
    out_path = args.out
    if out_path is None:
        out_path = ("BENCH_serve.smoke.json" if args.smoke
                    else "BENCH_serve.json")
    out = run(smoke=args.smoke, out_path=out_path or None)
    # every check gates, even under --smoke: drain-or-die, bitwise-or-
    # typed-shed, and breaker recovery are acceptance criteria
    raise SystemExit(0 if all(out["checks"].values()) else 1)
