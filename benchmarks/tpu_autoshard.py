"""Beyond-paper: BIDENT's search applied to TPU sharding strategies.

The TPU-mode Table-2 analog (DESIGN.md §2.2): for each assigned
architecture x step kind, the operator chain is costed under sharding
strategies (REP/DP/SP/TP/DP_TP/EP as "PUs") on the 16x16 v5e pod, and the
shortest-path search picks a per-operator strategy path.  Reported
against the best *single* strategy (the monolithic baseline — what a
hand-written sharding config does).

``direct`` additionally prices transitions as direct reshards instead of
the paper-faithful D2H(all-gather)+H2D(slice) over-approximation — the
first beyond-paper optimization of §Perf.
"""
from __future__ import annotations

from repro.configs import ALL_ARCHS, get_config
from repro.core.autoshard import autoshard
from repro.core.modelgraph import model_op_graph

from .common import geomean

KINDS = (("train", 256, 4096), ("prefill", 32, 32768), ("decode", 128, 32768))


def run(verbose: bool = True) -> dict:
    rows = {}
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for kind, B, S in KINDS:
            g = model_op_graph(cfg, kind=kind, batch=B, seq=S)
            r = autoshard(g, d_data=16, d_model=16)
            rd = autoshard(g, d_data=16, d_model=16, direct_reshard=True)
            re = autoshard(g, d_data=16, d_model=16, objective="energy")
            rows[(arch, kind)] = {
                "n_ops": len(g), "best_single": r.best_single,
                "single_ms": r.single[r.best_single] * 1e3,
                "bident_ms": r.schedule.latency * 1e3,
                "speedup": r.speedup, "speedup_direct": rd.speedup,
                "energy_red": 1.0 - re.schedule.energy / max(
                    min(v for v in [re.single[k] for k in re.single
                                    if re.single[k] is not None]), 1e-30),
            }
    sp = [r["speedup"] for r in rows.values()]
    spd = [r["speedup_direct"] for r in rows.values()]
    gm, gmd = geomean(sp), geomean(spd)
    dense_train = [rows[(a, "train")]["speedup"]
                   for a in ("llama3.2-1b", "mistral-large-123b", "qwen3-8b",
                             "stablelm-12b", "qwen2-vl-72b")]
    checks = {
        "BIDENT never below best single strategy": all(
            v >= 1.0 - 1e-9 for v in sp),
        "uniform dense train cells near-unity (paper LLaMA result)": all(
            v <= 1.05 for v in dense_train),
        "heterogeneous mixes gain (geomean %.2fx > 1.03)" % gm: gm > 1.03,
        "direct-reshard refinement >= paper-faithful (%.2fx >= %.2fx)" % (
            gmd, gm): gmd >= gm - 1e-9,
    }
    # paper regime (b) on TPU: intra-model branch parallelism.  Finding:
    # it does NOT transfer profitably — phase fork/join barriers imply
    # materialising branch inputs/outputs (gather-grade collectives),
    # which outweighs co-executing MoE branches on disjoint mesh slices.
    from repro.core.autoshard import autoshard_parallel
    g_moe = model_op_graph(get_config("deepseek-v3-671b"), kind="train",
                           batch=256, seq=4096)
    par = autoshard_parallel(g_moe, d_data=16, d_model=16)
    seq_moe = autoshard(g_moe, d_data=16, d_model=16)
    parallel_transfers = par.latency < seq_moe.schedule.latency
    checks["intra-model parallel negative-transfer documented "
           "(par %.1fs vs seq %.1fs)" % (par.latency, seq_moe.schedule.latency)
           ] = not parallel_transfers or True  # informational, always pass

    if verbose:
        print("== TPU autoshard (beyond-paper): per-op sharding search ==")
        print(f"{'arch':24s} {'kind':8s} {'ops':>5s} {'single':>10s} "
              f"{'BIDENT':>10s} {'spdup':>6s} {'direct':>7s}")
        for (arch, kind), r in rows.items():
            print(f"{arch:24s} {kind:8s} {r['n_ops']:5d} "
                  f"{r['single_ms']:8.2f}ms {r['bident_ms']:8.2f}ms "
                  f"{r['speedup']:5.2f}x {r['speedup_direct']:6.2f}x")
        print(f"geomean: {gm:.3f}x paper-faithful, {gmd:.3f}x with direct "
              "reshard")
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    return {"rows": {f"{a}|{k}": v for (a, k), v in rows.items()},
            "geomean": gm, "geomean_direct": gmd, "checks": checks}


if __name__ == "__main__":
    run()
