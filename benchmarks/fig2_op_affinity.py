"""Fig. 2: execution latency of seven representative operators per PU.

Paper claims validated here: GPU fastest for MatMul (2.8x vs CPU, 1.6x vs
NPU) and Conv2D (2.2x / 1.1x); CPU fastest for DWConv / Add / RDFT /
CumSum / Gather, with NPU penalties 4.7x / 8.7x / 4.1x on the non-GEMM
trio (RDFT / CumSum / Gather).
"""
from __future__ import annotations

from repro.core import EDGE_PUS, EdgeSoCCostModel
from repro.core.costmodel import FIG2_OPS

from .common import PUS


def run(verbose: bool = True) -> dict:
    m = EdgeSoCCostModel()
    rows = {}
    for name, op in FIG2_OPS.items():
        ts = {}
        for pu in PUS:
            e = m.entry(op, EDGE_PUS[pu])
            ts[pu] = e.w if e else None
        best = min(v for v in ts.values() if v)
        rows[name] = {k: (v / best if v else None) for k, v in ts.items()}

    checks = {
        "GPU fastest MatMul": rows["MatMul"]["GPU"] == 1.0,
        "MatMul CPU ~2.8x (got %.2f)" % rows["MatMul"]["CPU"]:
            2.3 <= rows["MatMul"]["CPU"] <= 3.3,
        "MatMul NPU ~1.6x (got %.2f)" % rows["MatMul"]["NPU"]:
            1.3 <= rows["MatMul"]["NPU"] <= 2.0,
        "GPU fastest Conv2D": rows["Conv2D"]["GPU"] == 1.0,
        "Conv2D CPU ~2.2x (got %.2f)" % rows["Conv2D"]["CPU"]:
            1.8 <= rows["Conv2D"]["CPU"] <= 2.7,
        "CPU fastest DWConv/Add/RDFT/CumSum/Gather": all(
            rows[k]["CPU"] == 1.0
            for k in ("DWConv", "Add", "RDFT", "CumSum", "Gather")),
        "RDFT NPU ~4.7x (got %.2f)" % rows["RDFT"]["NPU"]:
            3.8 <= rows["RDFT"]["NPU"] <= 5.7,
        "CumSum NPU ~8.7x (got %.2f)" % rows["CumSum"]["NPU"]:
            7.0 <= rows["CumSum"]["NPU"] <= 10.5,
        "Gather NPU ~4.1x (got %.2f)" % rows["Gather"]["NPU"]:
            3.3 <= rows["Gather"]["NPU"] <= 5.0,
    }
    if verbose:
        print("== Fig. 2: operator-to-PU affinity (normalized to fastest) ==")
        print(f"{'op':8s} " + " ".join(f"{p:>6s}" for p in PUS))
        for name, r in rows.items():
            print(f"{name:8s} " + " ".join(
                f"{r[p]:6.2f}" if r[p] else "   N/A" for p in PUS))
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    run()
