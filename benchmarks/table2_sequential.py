"""Table 2: sequential BIDENT orchestration over the paper's 19
model-precision configurations.

Columns reproduced: best single PU (the baseline), per-PU relative
latency, BIDENT-lat speedup, BIDENT-energy reduction.  Claims validated:
speedups >= 1 everywhere with geomean ~1.09x; largest gain on the
SNN-style heterogeneous op mix; near-unity for uniform op mixes
(LLaMA / KAN); energy-optimal search always reduces energy.
"""
from __future__ import annotations

from repro.core import EdgeSoCCostModel
from repro.core.paperzoo import zoo

from .common import PUS, geomean, sequential_report


def run(verbose: bool = True) -> dict:
    model = EdgeSoCCostModel()
    rows = {}
    for name, g in zoo().items():
        rows[name] = sequential_report(g, model)

    speedups = {k: r["speedup"] for k, r in rows.items()}
    gm = geomean(list(speedups.values()))
    uniform = [v for k, v in speedups.items()
               if k.startswith(("LLaMA", "KAN"))]
    checks = {
        "all speedups >= 1.0 (BIDENT never loses)": all(
            v >= 1.0 - 1e-9 for v in speedups.values()),
        "geomean ~1.09x (got %.3f)" % gm: 1.02 <= gm <= 1.30,
        "max speedup >= 1.3x on a heterogeneous mix (paper: SNN 1.58)":
            max(speedups.values()) >= 1.3,
        "SNN is the top gainer": max(
            speedups, key=speedups.get).startswith("SNN"),
        "uniform op mixes (LLaMA/KAN) near-unity (<=1.06)": all(
            v <= 1.06 for v in uniform),
        "energy-opt always reduces energy vs best single PU": all(
            r["energy_red_engopt"] >= -1e-9 for r in rows.values()),
    }
    if verbose:
        print("== Table 2: sequential orchestration ==")
        hdr = f"{'model':18s} {'best':4s} " + " ".join(
            f"{p:>5s}" for p in PUS) + f" {'BIDENT':>7s} {'spdup':>6s} {'E-red':>6s}"
        print(hdr)
        for name, r in rows.items():
            rel = {p: (r['single_lat'][p] / r['best_lat']
                       if r['single_lat'][p] else None) for p in PUS}
            print(f"{name:18s} {r['best']:4s} "
                  + " ".join(f"{rel[p]:5.2f}" if rel[p] else "  N/A"
                             for p in PUS)
                  + f" {r['bident_lat']/r['best_lat']:7.2f}"
                  + f" {r['speedup']:5.2f}x"
                  + f" {100*r['energy_red_engopt']:5.1f}%")
        print(f"geomean speedup: {gm:.3f}x (paper: 1.09x)")
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    return {"rows": {k: {kk: vv for kk, vv in r.items()
                         if kk not in ("table", "sched_l", "sched_e", "chain")}
                     for k, r in rows.items()},
            "geomean": gm, "checks": checks}


if __name__ == "__main__":
    run()
