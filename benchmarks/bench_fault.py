"""Fault-runtime benchmark: watchdog overhead on the fault-free path and
mid-run PU-loss recovery latency.

Two claims of the fault-tolerant execution runtime are quantitative, so
they get measured, recorded in ``BENCH_exec.json`` (under ``"fault"``),
and gated:

* **Fault-free overhead** — the watchdog instrumentation (deadline-bounded
  waits, abort checks, ``RunContext`` bookkeeping) must cost <= 10% on
  the warm-compiled path vs the pre-fault-runtime semantics, which remain
  available as ``ExecutionPolicy(watchdog=False)`` — the PR 5 baseline,
  measured in the same process so the ratio is machine-honest.  Serial
  programs skip the runtime entirely when fault-free (ratio ~1.0); the
  M=3 concurrent program exercises the real bounded-wait lane path.

* **Recovery latency** — a permanent PU loss injected mid-run must
  recover (re-plan remaining ops on surviving PUs + resume from the
  frontier) with outputs bitwise-identical to the fault-free run; the
  wall-clock cost of that loss → re-plan → resume cycle is recorded.

Both gates (overhead ratio geomean <= 1.10, bitwise recovery) are
enforced even under ``--smoke`` — they are the acceptance criteria of the
fault runtime, not informational timings.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import (EDGE_PUS, EdgeSoCCostModel, ExecutionPolicy,
                        FaultPlan, Orchestrator, results_bitwise_equal)
from repro.core.paperzoo import zoo

from .bench_exec import (SMOKE_MODELS, ZOO_MODELS, _best_of,
                         _concurrent_payload_models, attach_payloads)
from .common import env_meta, geomean

OVERHEAD_GATE = 1.10          # watchdog-on / watchdog-off, warm path
BASELINE = ExecutionPolicy(watchdog=False)    # PR 5 execution semantics


def _overhead_row(orch: Orchestrator, plan, inputs, repeats: int) -> dict:
    """Warm-path wall-clock with the watchdog on vs off (same process,
    same program cache — only the runtime instrumentation differs)."""
    orch.execute(plan, inputs)                      # compile + warm
    orch.execute(plan, inputs, policy=BASELINE)
    off_s = _best_of(
        lambda: orch.execute(plan, inputs, policy=BASELINE), repeats)
    on_s = _best_of(lambda: orch.execute(plan, inputs), repeats)
    return {
        "warm_off_ms": 1e3 * off_s,
        "warm_on_ms": 1e3 * on_s,
        "overhead_ratio": on_s / off_s,
    }


def _recovery_row(smoke: bool) -> dict:
    """Inject a permanent PU loss mid-run on an M=3 concurrent plan and
    time the loss → re-plan → resume cycle (interpreter path: the resume
    runs there, and the frontier semantics are identical on both)."""
    graphs, inputs = _concurrent_payload_models(8 if smoke else 16)
    orch = Orchestrator(EdgeSoCCostModel(), EDGE_PUS)
    plan = orch.plan([orch.register(g) for g in graphs])
    ref = orch.execute(plan, inputs, compile=False)
    ff_s = _best_of(lambda: orch.execute(plan, inputs, compile=False),
                    2 if smoke else 3)

    # fresh session per injected loss (recovery mutates the condition)
    orch2 = Orchestrator(EdgeSoCCostModel(), EDGE_PUS)
    plan2 = orch2.plan([orch2.register(g) for g in graphs])
    orch2.execute(plan2, inputs, compile=False)     # warm eager caches
    faults = FaultPlan.single("pu_lost", request=1,
                              op=len(graphs[1]) // 2)
    t0 = time.perf_counter()
    out = orch2.execute(plan2, inputs, compile=False, faults=faults)
    rec_s = time.perf_counter() - t0
    bitwise = all(results_bitwise_equal(a, b) for a, b in zip(out, ref))

    # transient retry cost: one injected transient, default backoff
    orch3 = Orchestrator(EdgeSoCCostModel(), EDGE_PUS)
    plan3 = orch3.plan([orch3.register(g) for g in graphs])
    orch3.execute(plan3, inputs, compile=False)
    tf = FaultPlan.single("transient", request=0, op=1)
    t0 = time.perf_counter()
    out_t = orch3.execute(plan3, inputs, compile=False, faults=tf)
    retry_s = time.perf_counter() - t0
    bitwise_t = all(results_bitwise_equal(a, b) for a, b in zip(out_t, ref))

    return {
        "n_ops": sum(len(g) for g in graphs),
        "fault_free_ms": 1e3 * ff_s,
        "pu_lost_recovered_ms": 1e3 * rec_s,
        "recovery_overhead_ms": 1e3 * (rec_s - ff_s),
        "recoveries": orch2.stats["recoveries"],
        "lost_pu": sorted(faults.lost),
        "bitwise_recovered": bitwise,
        "transient_retry_ms": 1e3 * retry_s,
        "bitwise_after_retry": bitwise_t,
    }


def run(verbose: bool = True, smoke: bool = False,
        out_path: str | None = None) -> dict:
    model = EdgeSoCCostModel()
    z = zoo()
    names = SMOKE_MODELS if smoke else ZOO_MODELS
    repeats = 15 if smoke else 40

    fault: dict = {"smoke": smoke, "overhead": {}, "recovery": {}}
    for name in names:
        g = z[name]
        inputs = attach_payloads(g)
        orch = Orchestrator(model, EDGE_PUS)
        plan = orch.plan(orch.register(g))
        fault["overhead"][name] = _overhead_row(orch, plan, inputs, repeats)

    graphs, inputs = _concurrent_payload_models(12 if smoke else 24)
    orch = Orchestrator(model, EDGE_PUS)
    cplan = orch.plan([orch.register(g) for g in graphs])
    fault["overhead"][f"M=3 x {len(graphs[0])} ops"] = _overhead_row(
        orch, cplan, inputs, repeats)

    fault["recovery"] = _recovery_row(smoke)

    ratios = [r["overhead_ratio"] for r in fault["overhead"].values()]
    ratio = geomean(ratios)
    fault["overhead_ratio_geomean"] = ratio
    rec = fault["recovery"]
    fault["checks"] = {
        "fault-free warm-compiled overhead of watchdog instrumentation "
        "<= %.0f%% vs watchdog-off baseline (geomean %.3fx)"
        % (100 * (OVERHEAD_GATE - 1), ratio): ratio <= OVERHEAD_GATE,
        "mid-run PU loss recovers bitwise-identical to the fault-free run":
            bool(rec["bitwise_recovered"] and rec["recoveries"] >= 1),
        "transient fault retries to bitwise-identical outputs":
            bool(rec["bitwise_after_retry"]),
    }

    if verbose:
        print(f"== fault-runtime benchmark ({'smoke' if smoke else 'full'}) ==")
        for name, r in fault["overhead"].items():
            print(f"  {name:24s} warm off {r['warm_off_ms']:7.3f}ms  "
                  f"on {r['warm_on_ms']:7.3f}ms  "
                  f"ratio {r['overhead_ratio']:.3f}x")
        print(f"  pu_lost: fault-free {rec['fault_free_ms']:.1f}ms -> "
              f"recovered {rec['pu_lost_recovered_ms']:.1f}ms "
              f"(+{rec['recovery_overhead_ms']:.1f}ms, "
              f"{rec['recoveries']} recovery, lost {rec['lost_pu']})  "
              f"bitwise={'OK' if rec['bitwise_recovered'] else 'FAIL'}")
        print(f"  transient retry: {rec['transient_retry_ms']:.1f}ms  "
              f"bitwise={'OK' if rec['bitwise_after_retry'] else 'FAIL'}")
        for c, ok in fault["checks"].items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")

    if out_path:
        # merge into the executor benchmark record rather than clobbering
        merged: dict = {}
        if os.path.exists(out_path):
            with open(out_path) as f:
                merged = json.load(f)
        merged["fault"] = fault
        merged["meta"] = env_meta()
        with open(out_path, "w") as f:
            json.dump(merged, f, indent=2)
        if verbose:
            print(f"wrote {out_path} (fault section)")
    return fault


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset (CI)")
    ap.add_argument("--out", default=None,
                    help="output JSON path ('' to skip writing; default "
                         "BENCH_exec.json, or BENCH_exec.smoke.json under "
                         "--smoke so the tracked full-run trajectory is "
                         "never clobbered by a smoke run)")
    args = ap.parse_args()
    out_path = args.out
    if out_path is None:
        out_path = "BENCH_exec.smoke.json" if args.smoke else "BENCH_exec.json"
    out = run(smoke=args.smoke, out_path=out_path or None)
    # every check gates, even under --smoke: the overhead ceiling and the
    # bitwise-recovery guarantee are acceptance criteria of the runtime
    raise SystemExit(0 if all(out["checks"].values()) else 1)
