"""Fig. 4: speedup of six ordered PU assignments for three independent
operator pairs vs the best serial single-PU baseline.

P1 = MatMul || Conv2D (two GPU-favoring ops), P2 = MatMul || CumSum
(GPU-favoring + CPU-favoring, the hybrid Transformer-Mamba case),
P3 = Conv2D || DWConv (split-preference convolutions).

Paper claims: GPU||CPU is the best assignment for every pair (1.41x /
1.38x / 1.46x); assignments that put the GEMM on the slower PU fall below
the serial baseline.  Makespans include the cross-PU contention SF.
"""
from __future__ import annotations

import itertools

from repro.core import ContentionModel, EDGE_PUS, EdgeSoCCostModel
from repro.core.costmodel import (make_conv2d, make_cumsum, make_dwconv,
                                  make_matmul)

from .common import PUS

PAIRS = {
    "P1 MatMul||Conv2D": (make_matmul(1024), make_conv2d(128, 128, 56, 3)),
    "P2 MatMul||CumSum": (make_matmul(1024), make_cumsum(4096, 256)),
    "P3 Conv2D||DWConv": (make_conv2d(128, 128, 56, 3),
                          make_dwconv(512, 112, 3)),
}


def run(verbose: bool = True) -> dict:
    m = EdgeSoCCostModel()
    cm = ContentionModel()
    results = {}
    for name, (op_a, op_b) in PAIRS.items():
        t = {}
        for pu in PUS:
            ea, eb = m.entry(op_a, EDGE_PUS[pu]), m.entry(op_b, EDGE_PUS[pu])
            t[pu] = (ea.w if ea else None, eb.w if eb else None)
        # best serial single-PU baseline: min over PUs of (t_a + t_b)
        serial = min(a + b for a, b in t.values() if a and b)
        rows = {}
        for pa, pb in itertools.product(PUS, PUS):
            if pa == pb:
                continue
            ta, tb = t[pa][0], t[pb][1]
            if ta is None or tb is None:
                continue
            # contention-adjusted parallel makespan (paper §3.3.2)
            mk = max(ta * cm.slowdown(pa, pb), tb * cm.slowdown(pb, pa))
            rows[f"{pa}||{pb}"] = serial / mk
        results[name] = {"serial_s": serial, "speedups": rows,
                         "best": max(rows, key=rows.get)}

    gpu_cpu_best = all(r["best"] in ("GPU||CPU", "CPU||GPU")
                       for r in results.values())
    best_vals = [max(r["speedups"].values()) for r in results.values()]
    checks = {
        "GPU||CPU (either order) best for every pair": gpu_cpu_best,
        "best parallel speedups in [1.2, 2.0] (paper 1.38-1.46)": all(
            1.2 <= v <= 2.0 for v in best_vals),
        "mis-assignments fall below serial baseline": all(
            min(r["speedups"].values()) < 1.0 for r in results.values()),
    }
    if verbose:
        print("== Fig. 4: parallel operator pairs vs best serial ==")
        for name, r in results.items():
            tops = sorted(r["speedups"].items(), key=lambda kv: -kv[1])
            print(f"{name}: best={r['best']} "
                  + " ".join(f"{k}={v:.2f}x" for k, v in tops))
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    return {"results": results, "checks": checks}


if __name__ == "__main__":
    run()
