"""Fig. 3: square MatMul [1,N,N]x[N,N] sweep, FP16 + INT8.

Paper claims validated: FP16 — CPU fastest through N=64, GPU crosses at
N=128 and widens to ~4.8x at N=2048.  INT8 — CPU leads through N=128, GPU
crosses at N=256, NPU overtakes GPU only at N=2048 (the only configuration
where the NPU is fastest).
"""
from __future__ import annotations

from repro.core import EDGE_PUS, EdgeSoCCostModel
from repro.core.costmodel import make_matmul

from .common import PUS

SIZES = (32, 64, 128, 256, 512, 1024, 2048)


def run(verbose: bool = True) -> dict:
    m = EdgeSoCCostModel()
    sweeps = {}
    for dtb, lbl in ((2, "FP16"), (1, "INT8")):
        rows = {}
        for n in SIZES:
            op = make_matmul(n, dtb)
            ts = {pu: m.entry(op, EDGE_PUS[pu]).w for pu in PUS}
            best = min(ts.values())
            rows[n] = {"win": min(ts, key=ts.get),
                       **{k: v / best for k, v in ts.items()}}
        sweeps[lbl] = rows

    f16, i8 = sweeps["FP16"], sweeps["INT8"]
    checks = {
        "FP16 CPU fastest N<=64": all(f16[n]["win"] == "CPU" for n in (32, 64)),
        "FP16 GPU crosses at N=128": f16[128]["win"] == "GPU",
        "FP16 GPU lead ~4.8x at 2048 (got %.2f)" % f16[2048]["CPU"]:
            4.0 <= f16[2048]["CPU"] <= 5.6,
        "INT8 CPU leads through N=128": all(
            i8[n]["win"] == "CPU" for n in (32, 64, 128)),
        "INT8 GPU crosses at N=256": i8[256]["win"] == "GPU",
        "INT8 NPU overtakes only at N=2048": (
            i8[2048]["win"] == "NPU"
            and all(i8[n]["win"] != "NPU" for n in SIZES[:-1])),
    }
    if verbose:
        print("== Fig. 3: MatMul size sweep (normalized to fastest) ==")
        for lbl, rows in sweeps.items():
            print(f"-- {lbl} --")
            for n, r in rows.items():
                print(f"  N={n:5d} win={r['win']:4s} " + " ".join(
                    f"{p}={r[p]:7.2f}" for p in PUS))
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    return {"sweeps": sweeps, "checks": checks}


if __name__ == "__main__":
    run()
