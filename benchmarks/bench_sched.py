"""Scheduler micro-benchmark: tracks the search engine's wall-clock
trajectory across PRs.

Times, across the model zoo:

* ``solve_sequential`` — vectorized DP vs explicit-graph Dijkstra vs the
  scalar DP reference;
* ``solve_parallel`` — phase/branch orchestration on the branchy graphs;
* ``solve_concurrent_joint`` — dense-table A* vs the reference dict-state
  Dijkstra at the seed's 48-segment granularity (the apples-to-apples
  speedup claim), plus A*-only timings at full operator resolution
  (where the reference is intractable: the seed needed coarsening);
* ``solve_concurrent`` with M >= 3 requests — the vectorized
  anti-diagonal grid sweep vs the retained heap grid A* at coarsened
  granularity (``grid_m``; the sweep must stay >= 5x faster on the M=3
  set), plus full-operator-resolution timings of the exact sweep and
  the rolling-horizon merge (``concurrent_m`` — the zoo M-sets now
  solve exactly at full resolution, under the raised state ceiling);
* the ``Orchestrator`` front door — cold ``plan`` (full solve through the
  router) vs a repeated identical ``plan`` served from the plan cache on
  the full-resolution fig8 zoo pairs, so the plan-cache win is tracked
  like the solver trajectory (``orchestrator`` section; the hit must stay
  >= 10x faster than the cold solve).

Writes ``BENCH_sched.json`` so subsequent PRs can diff the trajectory.
``--smoke`` runs a seconds-scale subset (used by CI).
"""
from __future__ import annotations

import json
import math
import os
import time

from repro.core import (ContentionModel, DEFAULT_MAX_STATES, EDGE_PUS,
                        EdgeSoCCostModel, Orchestrator, Workload,
                        solve_concurrent, solve_concurrent_joint,
                        solve_concurrent_joint_reference, solve_parallel,
                        solve_sequential)
from repro.core.paperzoo import zoo

from .common import env_meta, geomean, segment_table

SEQ_MODELS = ["ViT-B/16 FP16", "Hyena FP16", "pi0.5"]
PAR_MODELS = ["ViT-B/16 FP16", "SNN-VGG9 FP16"]
JOINT_PAIRS = [("ViT-B/16 FP16", "ResNet-50 FP16"),
               ("SNN-VGG9 FP16", "LAVISH FP16"),
               ("pi0.5", "Hyena FP16")]
M_SETS = [("ViT-B/16 FP16", "ResNet-50 FP16", "SNN-VGG9 FP16"),
          ("LLaMA-7B(1L) FP16", "Mamba-370M FP16", "KAN FP16",
           "LAVISH FP16")]
SMOKE_SEQ = ["ViT-B/16 FP16"]
SMOKE_PAIRS = [("ViT-B/16 FP16", "ResNet-50 FP16")]
SMOKE_M_SETS = [("LLaMA-7B(1L) FP16", "Mamba-370M FP16", "KAN FP16")]
M_GRID_SEGMENTS = 32   # grid granularity: (32+1)^3 ~ 36k states


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(verbose: bool = True, smoke: bool = False,
        out_path: str | None = "BENCH_sched.json") -> dict:
    model = EdgeSoCCostModel()
    cm = ContentionModel()
    z = zoo()
    repeats = 1 if smoke else 3
    seq_models = SMOKE_SEQ if smoke else SEQ_MODELS
    joint_pairs = SMOKE_PAIRS if smoke else JOINT_PAIRS
    par_models = SMOKE_SEQ if smoke else PAR_MODELS
    m_sets = SMOKE_M_SETS if smoke else M_SETS

    tables = {}
    for name in set(seq_models + par_models
                    + [n for p in joint_pairs for n in p]
                    + [n for s in m_sets for n in s]):
        g = z[name]
        tables[name] = (g, list(range(len(g))), model.build_table(g))

    out: dict = {"smoke": smoke, "sequential": {}, "parallel": {},
                 "joint_48seg": {}, "joint_fullres": {}, "grid_m": {},
                 "concurrent_m": {}, "orchestrator": {}}

    for name in seq_models:
        g, chain, table = tables[name]
        row = {"n_ops": len(g)}
        for algo in ("dp", "dijkstra", "dp_reference"):
            row[f"{algo}_ms"] = 1e3 * _best_of(
                lambda a=algo: solve_sequential(chain, g.ops, table,
                                                EDGE_PUS, algorithm=a),
                repeats)
        row["speedup_vs_dijkstra"] = row["dijkstra_ms"] / row["dp_ms"]
        out["sequential"][name] = row

    for name in par_models:
        g, chain, table = tables[name]
        out["parallel"][name] = {
            "n_ops": len(g),
            "ms": 1e3 * _best_of(
                lambda: solve_parallel(g, table, EDGE_PUS, cm), repeats)}

    for a, b in joint_pairs:
        ga, _, ta_full = tables[a]
        gb, _, tb_full = tables[b]
        ca, ta = segment_table(ga, ta_full, 48)
        cb, tb = segment_table(gb, tb_full, 48)
        astar_ms = 1e3 * _best_of(
            lambda: solve_concurrent_joint(ca, ta, cb, tb, EDGE_PUS, cm),
            repeats)
        ref_ms = 1e3 * _best_of(
            lambda: solve_concurrent_joint_reference(ca, ta, cb, tb,
                                                     EDGE_PUS, cm),
            repeats)
        out["joint_48seg"][f"{a} x {b}"] = {
            "astar_ms": astar_ms, "reference_ms": ref_ms,
            "speedup": ref_ms / astar_ms}

        c0, c1 = list(range(len(ga))), list(range(len(gb)))
        out["joint_fullres"][f"{a} x {b}"] = {
            "n0": len(ga), "n1": len(gb),
            "astar_ms": 1e3 * _best_of(
                lambda: solve_concurrent_joint(c0, ta_full, c1, tb_full,
                                               EDGE_PUS, cm),
                repeats)}

    for mset in m_sets:
        coarse, full = [], []
        for name in mset:
            g, chain, table = tables[name]
            cc, ct = segment_table(g, table, M_GRID_SEGMENTS)
            coarse.append(Workload.build(cc, ct, EDGE_PUS))
            full.append(Workload.build(chain, table, EDGE_PUS, ops=g.ops))
        n_states = math.prod(wl.n + 1 for wl in coarse)
        # heap A* vs vectorized sweep, same coarsened instance (the heap
        # is the slow retained oracle: time it once, the sweep best-of-N)
        if len(mset) == 3:
            astar_ms = 1e3 * _best_of(
                lambda: solve_concurrent(coarse, cm, algorithm="grid_astar",
                                         max_states=n_states), 1)
            sweep_ms = 1e3 * _best_of(
                lambda: solve_concurrent(coarse, cm, algorithm="grid",
                                         max_states=n_states), repeats)
            out["grid_m"][" x ".join(mset)] = {
                "m": len(mset), "grid_states": n_states,
                "astar_ms": astar_ms, "sweep_ms": sweep_ms,
                "speedup": astar_ms / sweep_ms}
        # full operator resolution: the exact sweep (the zoo M-sets fit
        # the raised state ceiling; a set outgrowing it records null and
        # fails the ceiling check below instead of crashing the run) +
        # the rolling and pairwise merges
        full_states = math.prod(wl.n + 1 for wl in full)
        fits = full_states <= DEFAULT_MAX_STATES
        row = {
            "m": len(mset),
            "grid_states_fullres": full_states,
            "grid_fullres_ms": (1e3 * _best_of(
                lambda: solve_concurrent(full, cm, algorithm="grid"),
                repeats)) if fits else None,
            "rolling_fullres_ms": 1e3 * _best_of(
                lambda: solve_concurrent(full, cm, algorithm="rolling"),
                repeats),
            "pairwise_fullres_ms": 1e3 * _best_of(
                lambda: solve_concurrent(full, cm, algorithm="pairwise"),
                repeats),
        }
        out["concurrent_m"][" x ".join(mset)] = row

    # orchestrator front door: cold plan (routed full solve) vs a second
    # identical plan served from the plan cache, at full op resolution
    for a, b in joint_pairs:
        ga, _, ta = tables[a]
        gb, _, tb = tables[b]
        cold_ms = float("inf")
        orch = None
        for _ in range(repeats):
            orch = Orchestrator(model, EDGE_PUS, cm)
            ha, hb = orch.register(ga, table=ta), orch.register(gb, table=tb)
            t0 = time.perf_counter()
            orch.plan((ha, hb))
            cold_ms = min(cold_ms, 1e3 * (time.perf_counter() - t0))
        hit_ms = 1e3 * _best_of(lambda: orch.plan((ha, hb)), 20)
        out["orchestrator"][f"{a} x {b}"] = {
            "cold_plan_ms": cold_ms, "cache_hit_ms": hit_ms,
            "speedup": cold_ms / hit_ms}

    joint_speedup = geomean([r["speedup"]
                             for r in out["joint_48seg"].values()])
    out["joint_48seg_geomean_speedup"] = joint_speedup
    orch_speedup = geomean([r["speedup"]
                            for r in out["orchestrator"].values()])
    out["orchestrator_geomean_speedup"] = orch_speedup
    grid_m_speedup = geomean([r["speedup"] for r in out["grid_m"].values()])
    out["grid_m_geomean_speedup"] = grid_m_speedup
    out["checks"] = {
        "joint A* >= 10x over reference Dijkstra at 48-segment granularity "
        "(geomean %.1fx)" % joint_speedup: joint_speedup >= 10.0,
        "vectorized DP faster than explicit-graph Dijkstra on every model":
            all(r["speedup_vs_dijkstra"] > 1.0
                for r in out["sequential"].values()),
        "vectorized M=3 grid sweep >= 5x over the retained heap A* "
        "(geomean %.1fx)" % grid_m_speedup: grid_m_speedup >= 5.0,
        "full-resolution M-sets solve exactly under the state ceiling":
            all(r["grid_states_fullres"] <= DEFAULT_MAX_STATES
                for r in out["concurrent_m"].values()),
        "orchestrator plan-cache hit >= 10x faster than cold plan "
        "(geomean %.0fx)" % orch_speedup: orch_speedup >= 10.0,
    }

    if verbose:
        print(f"== scheduler micro-benchmark ({'smoke' if smoke else 'full'}) ==")
        for name, r in out["sequential"].items():
            print(f"  seq {name:18s} n={r['n_ops']:5d}  dp {r['dp_ms']:8.2f}ms"
                  f"  dijkstra {r['dijkstra_ms']:8.2f}ms"
                  f"  scalar-dp {r['dp_reference_ms']:8.2f}ms")
        for name, r in out["parallel"].items():
            print(f"  par {name:18s} n={r['n_ops']:5d}  {r['ms']:8.2f}ms")
        for pair, r in out["joint_48seg"].items():
            print(f"  joint@48 {pair:32s} A* {r['astar_ms']:8.2f}ms"
                  f"  ref {r['reference_ms']:8.2f}ms  ({r['speedup']:.1f}x)")
        for pair, r in out["joint_fullres"].items():
            print(f"  joint@full {pair:30s} ({r['n0']}x{r['n1']} ops)"
                  f" A* {r['astar_ms']:8.2f}ms")
        for mset, r in out["grid_m"].items():
            print(f"  grid@{M_GRID_SEGMENTS}seg M={r['m']} {mset} "
                  f"({r['grid_states']} states)  heap A* "
                  f"{r['astar_ms']:8.2f}ms  sweep {r['sweep_ms']:8.2f}ms"
                  f"  ({r['speedup']:.1f}x)")
        for mset, r in out["concurrent_m"].items():
            gms = (f"{r['grid_fullres_ms']:8.2f}ms"
                   if r["grid_fullres_ms"] is not None else "over-cap")
            print(f"  M={r['m']} {mset}")
            print(f"       grid@full ({r['grid_states_fullres']} states) "
                  f"{gms}   "
                  f"rolling@full {r['rolling_fullres_ms']:8.2f}ms   "
                  f"pairwise@full {r['pairwise_fullres_ms']:8.2f}ms")
        for pair, r in out["orchestrator"].items():
            print(f"  orch {pair:34s} cold {r['cold_plan_ms']:8.2f}ms"
                  f"  hit {1e3*r['cache_hit_ms']:8.2f}us"
                  f"  ({r['speedup']:.0f}x)")
        for c, ok in out["checks"].items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")

    if out_path:
        out["meta"] = env_meta()
        # preserve sections other modules merge into this file (e.g.
        # bench_dag's "dag") instead of clobbering them
        merged = dict(out)
        if os.path.exists(out_path):
            try:
                with open(out_path) as f:
                    prev = json.load(f)
                for k, v in prev.items():
                    if k not in out:
                        merged[k] = v
            except (OSError, json.JSONDecodeError):
                pass
        with open(out_path, "w") as f:
            json.dump(merged, f, indent=2)
        if verbose:
            print(f"wrote {out_path}")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset (CI)")
    ap.add_argument("--out", default=None,
                    help="output JSON path ('' to skip writing; default "
                         "BENCH_sched.json, or BENCH_sched.smoke.json "
                         "under --smoke so the tracked full-run trajectory "
                         "is never clobbered by a smoke run)")
    args = ap.parse_args()
    out_path = args.out
    if out_path is None:
        out_path = ("BENCH_sched.smoke.json" if args.smoke
                    else "BENCH_sched.json")
    out = run(smoke=args.smoke, out_path=out_path or None)
    # wall-clock ratio checks are informational in --smoke (single-repeat
    # timings on shared CI runners are too noisy to gate a build on)
    raise SystemExit(0 if args.smoke or all(out["checks"].values()) else 1)
