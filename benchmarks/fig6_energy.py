"""Fig. 6: energy reduction of latency-optimized vs energy-optimized
schedules across all 19 configurations.

Paper claims validated: the energy-optimal schedule reduces energy vs the
best single-PU baseline on EVERY config (zero regressions, avg ~9.2%); the
latency-optimized schedule saves less on average (~3.7%) and REGRESSES on
several configs (paper: 5 of 19) because the latency objective is blind to
per-PU power; the energy objective trades some speedup (geomean lat 1.03x
vs 1.09x).
"""
from __future__ import annotations

from repro.core import EdgeSoCCostModel
from repro.core.paperzoo import zoo

from .common import geomean, sequential_report


def run(verbose: bool = True) -> dict:
    model = EdgeSoCCostModel()
    rows = {}
    for name, g in zoo().items():
        r = sequential_report(g, model)
        rows[name] = {
            "latopt_energy_red": r["energy_red_latopt"],
            "engopt_energy_red": r["energy_red_engopt"],
            "latopt_speedup": r["speedup"],
            "engopt_speedup": r["best_lat"] / r["bident_energy_lat"],
        }
    lat_reds = [r["latopt_energy_red"] for r in rows.values()]
    eng_reds = [r["engopt_energy_red"] for r in rows.values()]
    n_lat_regress = sum(1 for v in lat_reds if v < -1e-9)
    gm_lat = geomean([r["latopt_speedup"] for r in rows.values()])
    gm_eng = geomean([r["engopt_speedup"] for r in rows.values()])

    checks = {
        "energy-opt: zero energy regressions": all(v >= -1e-9 for v in eng_reds),
        "energy-opt avg reduction > lat-opt avg (%.1f%% vs %.1f%%)" % (
            100 * sum(eng_reds) / len(eng_reds),
            100 * sum(lat_reds) / len(lat_reds)):
            sum(eng_reds) > sum(lat_reds),
        "lat-opt regresses energy on >=1 config (paper: 5/19, got %d)"
        % n_lat_regress: n_lat_regress >= 1,
        "energy objective trades speedup (geomean %.3f <= %.3f)" % (
            gm_eng, gm_lat): gm_eng <= gm_lat + 1e-9,
    }
    if verbose:
        print("== Fig. 6: latency-opt vs energy-opt schedules ==")
        print(f"{'model':18s} {'lat-opt E-red':>14s} {'eng-opt E-red':>14s}")
        for name, r in rows.items():
            print(f"{name:18s} {100*r['latopt_energy_red']:13.1f}% "
                  f"{100*r['engopt_energy_red']:13.1f}%")
        print(f"avg: lat-opt {100*sum(lat_reds)/len(lat_reds):.1f}% "
              f"(paper 3.7%), eng-opt {100*sum(eng_reds)/len(eng_reds):.1f}% "
              f"(paper 9.2%); lat-opt regressions: {n_lat_regress} (paper 5)")
        print(f"geomean speedup: eng-opt {gm_eng:.3f}x vs lat-opt {gm_lat:.3f}x "
              f"(paper 1.03x vs 1.09x)")
        for c, ok in checks.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    run()
