"""Serving benchmark: warm-start incremental re-planning + streaming load.

Two sections, written to ``BENCH_serve.json``:

* ``replan`` — event replay on the M=3 fig8 zoo set (ViT-B/16 FP16 x
  ResNet-50 FP16 x SNN-VGG9 FP16 at full operator resolution, ~789k
  grid states).  Each event is a mid-flight re-plan at a progress
  vector; for every event we time

  - **warm**: the pooled :class:`IncrementalConcurrentSolver` with a
    bounded re-plan window (``horizon_states``) — the post-PR serving
    path (one untimed warm-up solve builds the shared tables first,
    matching the serving steady state);
  - **cold same-op**: the identical windowed solve
    (:func:`solve_concurrent_horizon`) from *fresh* caches — the bitwise
    oracle: every warm plan must equal it step-for-step (ops, PUs,
    bitwise float costs, latency, energy);
  - **cold full**: ``solve_concurrent`` on the remaining tails from
    fresh caches — what a re-plan event cost before this PR (the
    orchestrator re-solved the whole remaining grid on every
    admit/advance/retire).

  Gate: geomean(cold full / warm) >= 5x, and bitwise identity on every
  event.  Both gates are enforced in ``--smoke`` too — identity and the
  re-plan speedup are the PR's claim, not a noisy wall-clock trend.
  The same-op ratio (cold windowed / warm windowed) is reported as a
  secondary cache-effectiveness metric but not gated: it isolates table
  reuse, while the serving win is window + reuse together.

* ``serving`` — :class:`ServingEngine` runs on Poisson and bursty
  arrival traces over the same zoo models: sustained throughput,
  p50/p99 wall-clock *plan* latency, p50/p99 virtual *request* latency,
  and the warm/cold re-plan split.  Gate: zero cold re-plans — every
  serving-loop event must take the incremental path.
"""
from __future__ import annotations

import json
import time

from repro.core import (ArrivalTrace, ConcurrentCaches, EDGE_PUS,
                        EdgeSoCCostModel, IncrementalConcurrentSolver,
                        Orchestrator, ServingEngine, Workload,
                        solve_concurrent, solve_concurrent_horizon)
from repro.core.paperzoo import zoo

from .common import env_meta, geomean

M_SET = ("ViT-B/16 FP16", "ResNet-50 FP16", "SNN-VGG9 FP16")
HORIZON_STATES = 1_024

# progress vectors (fractions of each chain) where a serving re-plan
# would fire: admissions and advances across the first ~70% of the run
EVENT_FRACS = [(0.0, 0.0, 0.0), (0.1, 0.1, 0.1), (0.2, 0.2, 0.2),
               (0.3, 0.3, 0.3), (0.4, 0.4, 0.4), (0.5, 0.5, 0.5),
               (0.6, 0.6, 0.6), (0.7, 0.7, 0.7)]
SMOKE_FRACS = [(0.1, 0.1, 0.1), (0.5, 0.5, 0.5)]
ENERGY_FRACS = [(0.2, 0.2, 0.2), (0.5, 0.5, 0.5)]
SMOKE_ENERGY_FRACS = [(0.5, 0.5, 0.5)]


def _best_of(fn, repeats: int) -> tuple[float, object]:
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _workloads():
    model = EdgeSoCCostModel()
    wls = []
    for name in M_SET:
        g = zoo()[name]
        t = model.build_table(g)
        wls.append(Workload.build(list(range(len(g))), t, EDGE_PUS,
                                  ops=g.ops))
    return wls


def _bitwise_equal(a, b) -> bool:
    return (a.latency == b.latency and a.energy == b.energy
            and a.steps == b.steps)


def _replay(smoke: bool, repeats: int, verbose: bool) -> dict:
    wls = _workloads()
    ns = [wl.n for wl in wls]
    fracs = SMOKE_FRACS if smoke else EVENT_FRACS
    energy_fracs = SMOKE_ENERGY_FRACS if smoke else ENERGY_FRACS
    events = [tuple(int(f * n) for f, n in zip(fs, ns)) for fs in fracs]
    energy_events = [tuple(int(f * n) for f, n in zip(fs, ns))
                     for fs in energy_fracs]

    inc = IncrementalConcurrentSolver(wls, caches=ConcurrentCaches())
    inc.solve([0] * len(wls), "latency",
              horizon_states=HORIZON_STATES)   # untimed pool warm-up
    inc.solve([0] * len(wls), "energy", horizon_states=HORIZON_STATES)

    rows = []
    for objective, evs in (("latency", events), ("energy", energy_events)):
        for prog in evs:
            warm_s, warm = _best_of(
                lambda: inc.solve(list(prog), objective,
                                  horizon_states=HORIZON_STATES), repeats)
            if warm is None:
                raise AssertionError(
                    f"warm solver delegated at {prog}/{objective}: the "
                    f"default-coexec zoo set must stay incremental")
            tails = [wl.tail(p) for wl, p in zip(wls, prog)]
            cold_win_s, cold_win = _best_of(
                lambda: solve_concurrent_horizon(
                    tails, None, objective, caches=ConcurrentCaches(),
                    horizon_states=HORIZON_STATES), repeats)
            cold_full_s, _ = _best_of(
                lambda: solve_concurrent(tails, None, objective,
                                         caches=ConcurrentCaches()),
                repeats)
            rows.append({
                "progress": list(prog), "objective": objective,
                "warm_ms": warm_s * 1e3,
                "cold_windowed_ms": cold_win_s * 1e3,
                "cold_full_ms": cold_full_s * 1e3,
                "replan_speedup": cold_full_s / warm_s,
                "same_op_speedup": cold_win_s / warm_s,
                "bitwise": _bitwise_equal(warm, cold_win),
            })
            if verbose:
                r = rows[-1]
                print(f"  {objective:7s} @{str(prog):15s} "
                      f"warm {r['warm_ms']:7.2f}ms  "
                      f"cold-win {r['cold_windowed_ms']:7.2f}ms  "
                      f"cold-full {r['cold_full_ms']:8.2f}ms  "
                      f"({r['replan_speedup']:6.1f}x, "
                      f"same-op {r['same_op_speedup']:4.1f}x)  "
                      f"bitwise={'OK' if r['bitwise'] else 'FAIL'}")
    return {"m_set": list(M_SET), "n_states": ns,
            "horizon_states": HORIZON_STATES, "events": rows,
            "replan_geomean_speedup": geomean(
                [r["replan_speedup"] for r in rows]),
            "same_op_geomean_speedup": geomean(
                [r["same_op_speedup"] for r in rows]),
            "all_bitwise": all(r["bitwise"] for r in rows)}


def _serving(smoke: bool, verbose: bool) -> dict:
    n = 12 if smoke else 50
    graphs = {name: zoo()[name] for name in M_SET}
    out = {}
    for kind, trace in (
            ("poisson", ArrivalTrace.poisson(list(M_SET), rate=4.0, n=n,
                                             seed=0)),
            ("bursty", ArrivalTrace.bursty(list(M_SET), rate=40.0, n=n,
                                           burst_every=5, burst_size=3,
                                           seed=1))):
        orch = Orchestrator(EdgeSoCCostModel())
        eng = ServingEngine(orch, graphs, horizon_states=HORIZON_STATES,
                            max_concurrent=3)
        rep = eng.serve(trace)
        out[kind] = rep.to_dict()
        if verbose:
            print(f"  {kind:8s} n={rep.n_requests:3d} done={rep.completed} "
                  f"shed={rep.shed}  {rep.throughput:6.1f} req/s  "
                  f"plan p50/p99 {rep.plan_ms_p50:.2f}/"
                  f"{rep.plan_ms_p99:.2f}ms  "
                  f"latency p50/p99 {1e3*rep.latency_p50:.1f}/"
                  f"{1e3*rep.latency_p99:.1f}ms  "
                  f"warm/cold {rep.replans_warm}/{rep.replans_cold}")
    return out


def run(verbose: bool = True, smoke: bool = False,
        out_path: str | None = "BENCH_serve.json") -> dict:
    repeats = 1 if smoke else 3
    if verbose:
        print(f"== serving benchmark ({'smoke' if smoke else 'full'}) ==")
        print(f"-- incremental re-plan event replay (M=3 fig8 zoo) --")
    replan = _replay(smoke, repeats, verbose)
    if verbose:
        print(f"-- streaming serving (ServingEngine) --")
    serving = _serving(smoke, verbose)

    speedup = replan["replan_geomean_speedup"]
    cold = sum(serving[k]["replans_cold"] for k in serving)
    served = all(serving[k]["completed"] + serving[k]["shed"]
                 == serving[k]["n_requests"] for k in serving)
    out = {"smoke": smoke, "replan": replan, "serving": serving,
           "checks": {
               "every warm re-plan is bitwise-identical to the cold "
               "windowed solve": replan["all_bitwise"],
               "warm re-plan >= 5x faster than pre-PR cold full re-solve "
               "(geomean %.1fx)" % speedup: speedup >= 5.0,
               "serving loop never falls back to a cold re-plan "
               "(%d cold)" % cold: cold == 0,
               "every request is completed or explicitly shed": served,
           }}
    if verbose:
        for c, ok in out["checks"].items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")
    if out_path:
        out["meta"] = env_meta()
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
        if verbose:
            print(f"wrote {out_path}")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset (CI); bitwise + >=5x gates "
                         "still enforced")
    ap.add_argument("--out", default=None,
                    help="output JSON path ('' to skip writing; default "
                         "BENCH_serve.json, or BENCH_serve.smoke.json "
                         "under --smoke so the tracked full-run trajectory "
                         "is never clobbered by a smoke run)")
    args = ap.parse_args()
    out_path = args.out
    if out_path is None:
        out_path = ("BENCH_serve.smoke.json" if args.smoke
                    else "BENCH_serve.json")
    out = run(smoke=args.smoke, out_path=out_path or None)
    # unlike the wall-clock trend benchmarks, these checks hold in smoke
    # too: bitwise identity is exact, and the >=5x re-plan margin is wide
    # (~order of magnitude), not a noisy single-repeat ratio
    raise SystemExit(0 if all(out["checks"].values()) else 1)
