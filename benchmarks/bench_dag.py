"""DAG-route benchmark: dispatch overhead, oracle equivalence, and the
VLA intra-model-parallelism win.

* **linear-DAG overhead** — ``solve_dag`` on a linear chain dispatches
  to the sequential chain DP; the front door must cost <= 1.1x the
  direct ``solve_sequential`` call.  Measured as interleaved
  best-of-repeats pairs (the two sides alternate within one loop, and
  each side's minimum is its intrinsic cost) so shared-machine drift
  cancels instead of landing on whichever side ran second.
* **oracle equivalence** — the dispatch routes must stay bitwise: chain
  DP on linear DAGs, anti-diagonal grid sweep on unions of chains,
  ``solve_parallel`` on fork/join DAGs, and the frontier generalization
  reducing to the sweep on unions (deterministic booleans, not timings).
* **VLA win** — the paper's vision||language->fusion->action-head
  pipeline: the DAG plan (and specifically the antichain-frontier
  route's step-level co-schedules) must beat the best sequential route
  on modeled latency.

Merges a ``"dag"`` section into ``BENCH_sched.json`` — the scheduler
trajectory file — instead of owning a separate artifact.  ``--smoke``
runs a seconds-scale subset (used by CI; all gates enforced — the
equivalence and modeled-latency gates are deterministic, and the
overhead gate is a best-of-repeats, not a single sample).
"""
from __future__ import annotations

import gc
import json
import os
import time

import numpy as np

from repro.core import (ContentionModel, EDGE_PUS, EdgeSoCCostModel, FusedOp,
                        OpGraph, Workload, chain_graph, solve_concurrent,
                        solve_dag, solve_parallel, solve_sequential)
from repro.core.paperzoo import lavish, vla_pipeline

from .common import env_meta, geomean

CHAIN_SIZES_SMOKE = (256,)
CHAIN_SIZES_FULL = (256, 2048)
OVERHEAD_GATE = 1.1


def _best_pair(fn_a, fn_b, repeats: int) -> tuple[float, float]:
    """Interleaved best-of-``repeats`` for two rival callables.

    The pair alternates inside one loop and each side keeps its minimum:
    the minimum estimates intrinsic cost (noise only ever adds time) and
    interleaving ensures slow-machine drift lands on both sides alike.
    GC is paused so collection pauses don't land on whichever side
    allocates more objects.
    """
    fn_a(), fn_b()                         # warm caches / allocator
    best_a = best_b = float("inf")
    gc_was_on = gc.isenabled()
    gc.disable()
    try:
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn_a()
            best_a = min(best_a, time.perf_counter() - t0)
            t0 = time.perf_counter()
            fn_b()
            best_b = min(best_b, time.perf_counter() - t0)
    finally:
        if gc_was_on:
            gc.enable()
    return best_a, best_b


def _synthetic_chain(n: int, seed: int = 0) -> OpGraph:
    rng = np.random.default_rng(seed)
    kinds = ("matmul", "add", "norm", "act", "cumsum")
    ops = []
    for i in range(n):
        kind = kinds[int(rng.integers(len(kinds)))]
        if kind == "matmul":
            sz = int(rng.integers(64, 512))
            ops.append(FusedOp(name=f"c{i}", kind=kind,
                               in_shapes=((1, sz, sz), (sz, sz)),
                               out_shape=(1, sz, sz)))
        else:
            numel = int(rng.integers(10_000, 1_000_000))
            ops.append(FusedOp(name=f"c{i}", kind=kind,
                               in_shapes=((numel,),), out_shape=(numel,)))
    return chain_graph(ops)


def _union_graph() -> OpGraph:
    chains = [3, 2, 3]
    n = sum(chains)
    ops = [FusedOp(name=f"u{i}", kind="matmul",
                   in_shapes=((1, 128, 128), (128, 128)),
                   out_shape=(1, 128, 128)) for i in range(n)]
    edges, k = [], 0
    for ln in chains:
        ids = list(range(k, k + ln))
        edges += list(zip(ids, ids[1:]))
        k += ln
    return OpGraph(ops, edges=edges)


def run(verbose: bool = True, smoke: bool = False,
        out_path: str | None = "BENCH_sched.json") -> dict:
    model = EdgeSoCCostModel()
    cm = ContentionModel()
    repeats = 25
    sizes = CHAIN_SIZES_SMOKE if smoke else CHAIN_SIZES_FULL

    out: dict = {"smoke": smoke, "linear_overhead": {}, "equivalence": {},
                 "vla": {}}

    # -- linear-DAG dispatch overhead vs the chain DP ----------------------
    ratios = []
    for n in sizes:
        g = _synthetic_chain(n)
        table = model.build_table(g)
        # both sides start from the graph: solve_dag derives the chain
        # order internally, so the direct call must pay for it too
        dp_s, dag_s = _best_pair(
            lambda: solve_sequential(g.topo_order(), g.ops, table,
                                     EDGE_PUS),
            lambda: solve_dag(g, table, EDGE_PUS, cm), repeats)
        ratio = dag_s / dp_s
        ratios.append(ratio)
        out["linear_overhead"][f"chain_{n}"] = {
            "n_ops": n, "chain_dp_ms": 1e3 * dp_s,
            "solve_dag_ms": 1e3 * dag_s, "overhead": ratio}
    overhead = max(ratios)

    # -- oracle equivalence (deterministic, bitwise) -----------------------
    g = _synthetic_chain(64, seed=3)
    table = model.build_table(g)
    dag = solve_dag(g, table, EDGE_PUS, cm)
    seq = solve_sequential(g.topo_order(), g.ops, table, EDGE_PUS)
    out["equivalence"]["linear_bitwise_chain_dp"] = bool(
        dag.mode == "chain" and dag.latency == seq.latency
        and dag.energy == seq.energy
        and [dag.assignment[o] for o in seq.chain] == list(seq.assignment))

    gu = _union_graph()
    tu = model.build_table(gu)
    du = solve_dag(gu, tu, EDGE_PUS, cm)
    wlu = Workload.from_graph(gu, tu, EDGE_PUS)
    grid = solve_concurrent([wlu.select(c) for c in gu.components()], cm,
                            algorithm="grid")
    out["equivalence"]["union_bitwise_grid_sweep"] = bool(
        du.mode == "union-grid" and du.latency == grid.latency
        and du.energy == grid.energy)

    fu = solve_dag(gu, tu, EDGE_PUS, cm, algorithm="frontier")
    out["equivalence"]["frontier_reduces_to_grid_on_union"] = bool(
        fu.latency == grid.latency and fu.energy == grid.energy)

    gb = lavish()
    tb = model.build_table(gb)
    db = solve_dag(gb, tb, EDGE_PUS, cm)
    par = solve_parallel(gb, tb, EDGE_PUS, cm)
    out["equivalence"]["branch_bitwise_solve_parallel"] = bool(
        db.mode == "phase" and db.latency == par.latency
        and db.energy == par.energy)
    equivalent = all(out["equivalence"].values())

    # -- the VLA scenario: co-execution beats the best sequential route ----
    gv = vla_pipeline()
    tv = model.build_table(gv)
    seq_v = solve_sequential(gv.topo_order(), gv.ops, tv, EDGE_PUS)
    fr_v = solve_dag(gv, tv, EDGE_PUS, cm, algorithm="frontier")
    ph_v = solve_dag(gv, tv, EDGE_PUS, cm)          # auto -> phase
    out["vla"] = {
        "n_ops": len(gv.ops),
        "sequential_ms": 1e3 * seq_v.latency,
        "dag_plan_ms": 1e3 * ph_v.latency,
        "frontier_ms": 1e3 * fr_v.latency,
        "frontier_parallel_steps": fr_v.n_parallel_steps,
        "dag_speedup_vs_sequential": seq_v.latency / ph_v.latency,
        "frontier_speedup_vs_sequential": seq_v.latency / fr_v.latency,
    }

    out["checks"] = {
        "linear-DAG dispatch overhead <= %.1fx the chain DP (max %.3fx)"
        % (OVERHEAD_GATE, overhead): overhead <= OVERHEAD_GATE,
        "DAG route bitwise-identical to its oracle on every shape":
            equivalent,
        "VLA DAG plan beats the best sequential route (%.2fx)"
        % out["vla"]["dag_speedup_vs_sequential"]:
            ph_v.latency < seq_v.latency,
        "VLA frontier co-schedules beat the best sequential route (%.2fx)"
        % out["vla"]["frontier_speedup_vs_sequential"]:
            fr_v.latency < seq_v.latency and fr_v.n_parallel_steps > 0,
    }

    if verbose:
        print(f"== DAG-route benchmark ({'smoke' if smoke else 'full'}) ==")
        for name, r in out["linear_overhead"].items():
            print(f"  {name:12s} chain-dp {r['chain_dp_ms']:8.2f}ms   "
                  f"solve_dag {r['solve_dag_ms']:8.2f}ms   "
                  f"({r['overhead']:.3f}x)")
        for name, ok in out["equivalence"].items():
            print(f"  equiv {name:38s} {ok}")
        v = out["vla"]
        print(f"  VLA ({v['n_ops']} ops)  sequential {v['sequential_ms']:.4f}ms"
              f"   dag {v['dag_plan_ms']:.4f}ms"
              f" ({v['dag_speedup_vs_sequential']:.2f}x)"
              f"   frontier {v['frontier_ms']:.4f}ms"
              f" ({v['frontier_speedup_vs_sequential']:.2f}x, "
              f"{v['frontier_parallel_steps']} co-scheduled steps)")
        for c, ok in out["checks"].items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {c}")

    if out_path:
        # merge into the scheduler trajectory file rather than owning a
        # separate artifact: everything else in the file survives
        data: dict = {}
        if os.path.exists(out_path):
            try:
                with open(out_path) as f:
                    data = json.load(f)
            except (OSError, json.JSONDecodeError):
                data = {}
        section = dict(out)
        section["meta"] = env_meta()
        data["dag"] = section
        with open(out_path, "w") as f:
            json.dump(data, f, indent=2)
        if verbose:
            print(f"merged 'dag' section into {out_path}")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset (CI); gates still enforced")
    ap.add_argument("--out", default=None,
                    help="trajectory JSON to merge the 'dag' section into "
                         "('' to skip writing; default BENCH_sched.json, "
                         "or BENCH_sched.smoke.json under --smoke)")
    args = ap.parse_args()
    out_path = args.out
    if out_path is None:
        out_path = ("BENCH_sched.smoke.json" if args.smoke
                    else "BENCH_sched.json")
    out = run(smoke=args.smoke, out_path=out_path or None)
    raise SystemExit(0 if all(out["checks"].values()) else 1)
