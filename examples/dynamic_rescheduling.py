"""Dynamic operator-level rescheduling — the paper's §6 future work, live.

A hybrid GEMM+scan workload runs under a static BIDENT schedule; halfway
through, the GPU thermally throttles 4x.  The dynamic scheduler detects
the drift, re-runs the shortest-path search over the remaining tail
(sub-millisecond), and reroutes — beating the static schedule.

Run:  PYTHONPATH=src python examples/dynamic_rescheduling.py
"""
from repro.core import EDGE_PUS, AnalyticProfiler, OpGraph
from repro.core.costmodel import make_cumsum, make_matmul
from repro.core.dynamic import DynamicScheduler, RuntimeCondition

ops = []
for i in range(12):
    ops.append(make_matmul(512, name=f"mm{i}") if i % 2 == 0
               else make_cumsum(4096, 128))
g = OpGraph(ops)
table = AnalyticProfiler().profile(g)
chain = g.topo_order()

event = {6: RuntimeCondition(slowdown={"GPU": 4.0})}
print("event: GPU throttles 4.0x before op 6\n")

dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
plan_before = list(dyn.plan.assignment)
t_dyn = dyn.simulate(event)

static = DynamicScheduler(chain, g.ops, table, EDGE_PUS,
                          replan_threshold=1e9)
t_static = static.simulate(event)

print(f"static plan : {plan_before}")
print(f"dynamic plan: {dyn.plan.assignment}")
for e in dyn.events:
    print(f"remap at op {e.at_op} ({e.reason}): tail "
          f"{e.old_tail_cost*1e3:.2f} -> {e.new_tail_cost*1e3:.2f} ms predicted")
# the stitched plan carries real re-evaluated numbers (prefix at the
# nominal profile, tail under the throttled condition) — no NaNs
print(f"stitched plan: {dyn.plan.latency*1e3:.2f} ms / "
      f"{dyn.plan.energy*1e3:.2f} mJ predicted")
print(f"\nrealised latency: static {t_static*1e3:.2f} ms, "
      f"dynamic {t_dyn*1e3:.2f} ms ({t_static/t_dyn:.2f}x)")
assert t_dyn < t_static
