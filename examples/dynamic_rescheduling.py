"""Dynamic operator-level rescheduling — the paper's §6 future work, live,
through the orchestrator's condition hook.

A hybrid GEMM+scan workload is registered and admitted; halfway through,
the GPU thermally throttles 4x.  ``orch.on_condition`` invalidates the
cached plans priced under the stale GPU assumption and re-plans the
active request through its ``DynamicScheduler`` from current progress
(sub-millisecond tail re-search, hysteresis included), returning the
stitched plan — prefix at the nominal profile, tail under the throttled
condition.  The low-level ``DynamicScheduler.simulate`` then replays the
whole chain to compare realised latencies against a static schedule.

The second act is the mid-run case the condition hook alone can't cover:
a PU dies *during* execution.  A scripted ``FaultPlan`` kills a lane
partway through a real run; the executor surfaces the loss with the
completed-results frontier attached, and ``orch.execute`` recovers —
re-plans the remaining ops on the surviving PUs and resumes — with
outputs bitwise-identical to the fault-free run.

Run:  PYTHONPATH=src python examples/dynamic_rescheduling.py
"""
import numpy as np

from repro.core import (EDGE_PUS, AnalyticProfiler, FaultPlan, FusedOp,
                        OpGraph, Orchestrator, RuntimeCondition,
                        chain_graph, results_bitwise_equal)
from repro.core.costmodel import make_cumsum, make_matmul
from repro.core.dynamic import DynamicScheduler

ops = []
for i in range(12):
    ops.append(make_matmul(512, name=f"mm{i}") if i % 2 == 0
               else make_cumsum(4096, 128))
g = OpGraph(ops)

orch = Orchestrator(AnalyticProfiler())
h = orch.register(g)
plan0 = orch.plan(h)
print("event: GPU throttles 4.0x before op 6\n")

# the serving view: the request is active and 6 ops in when the
# monitoring condition arrives
orch.admit(h)
orch.advance(h, 6)
restitched = orch.on_condition(
    RuntimeCondition(slowdown={"GPU": 4.0}))[(h, "latency")]
print(f"static plan : {plan0.schedule.assignment}")
print(f"dynamic plan: {restitched.schedule.assignment}")
for e in orch.dynamic(h).events:
    print(f"remap at op {e.at_op} ({e.reason}): tail "
          f"{e.old_tail_cost*1e3:.2f} -> {e.new_tail_cost*1e3:.2f} ms predicted")
# the stitched plan carries real re-evaluated numbers (prefix at the
# nominal profile, tail under the throttled condition) — no NaNs
print(f"stitched plan: {restitched.latency*1e3:.2f} ms / "
      f"{restitched.energy*1e3:.2f} mJ predicted")
# cached nominal plans priced with GPU@1.0 were invalidated per-PU
print(f"plan cache after invalidation: {orch.stats}")

# -- realised latency: replay on the low-level DynamicScheduler ----------
event = {6: RuntimeCondition(slowdown={"GPU": 4.0})}
table = orch.workload(h).table
chain = g.topo_order()
dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
t_dyn = dyn.simulate(event)
static = DynamicScheduler(chain, g.ops, table, EDGE_PUS,
                          replan_threshold=1e9)
t_static = static.simulate(event)
print(f"\nrealised latency: static {t_static*1e3:.2f} ms, "
      f"dynamic {t_dyn*1e3:.2f} ms ({t_static/t_dyn:.2f}x)")
assert t_dyn < t_static
assert dyn.plan.assignment == restitched.schedule.assignment

# -- mid-run PU loss: fault injection + re-plan-and-resume recovery ------
import jax.numpy as jnp  # noqa: E402  (the fault demo runs real payloads)

print("\nevent: the lane holding op 5 dies permanently DURING execution\n")
ops2 = []
for i in range(10):
    c = jnp.float32(1.0 + 0.01 * i)
    ops2.append(FusedOp(name=f"f{i}", kind="matmul", flops=1e7,
                        bytes_moved=1e5,
                        fn=(lambda c: lambda x: jnp.tanh(x * c))(c)))
g2 = chain_graph(ops2)
x0 = jnp.asarray(np.linspace(-1, 1, 64, dtype=np.float32).reshape(8, 8))
inputs = {0: (x0,)}

orch2 = Orchestrator(AnalyticProfiler())
plan2 = orch2.plan(orch2.register(g2))
reference = orch2.execute(plan2, inputs)          # fault-free run

# kill whatever lane op 5 lands on, the moment it is dispatched
faults = FaultPlan.single("pu_lost", request=0, op=5)
recovered = orch2.execute(plan2, inputs, faults=faults)

lost = next(iter(faults.lost))
print(f"lost PU      : {lost} (at op 5; injected via FaultPlan)")
print(f"recoveries   : {orch2.stats['recoveries']} "
      f"(condition now marks {sorted(orch2.condition.unavailable)} "
      "unavailable; stale cached plans were invalidated)")
replanned = orch2.plan(plan2.handles)
print(f"re-planned   : {replanned.schedule.assignment} (survivors only)")
assert lost not in replanned.schedule.assignment
assert results_bitwise_equal(recovered, reference)
print("recovered outputs are bitwise-identical to the fault-free run")
