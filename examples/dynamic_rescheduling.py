"""Dynamic operator-level rescheduling — the paper's §6 future work, live,
through the orchestrator's condition hook.

A hybrid GEMM+scan workload is registered and admitted; halfway through,
the GPU thermally throttles 4x.  ``orch.on_condition`` invalidates the
cached plans priced under the stale GPU assumption and re-plans the
active request through its ``DynamicScheduler`` from current progress
(sub-millisecond tail re-search, hysteresis included), returning the
stitched plan — prefix at the nominal profile, tail under the throttled
condition.  The low-level ``DynamicScheduler.simulate`` then replays the
whole chain to compare realised latencies against a static schedule.

Run:  PYTHONPATH=src python examples/dynamic_rescheduling.py
"""
from repro.core import (EDGE_PUS, AnalyticProfiler, OpGraph, Orchestrator,
                        RuntimeCondition)
from repro.core.costmodel import make_cumsum, make_matmul
from repro.core.dynamic import DynamicScheduler

ops = []
for i in range(12):
    ops.append(make_matmul(512, name=f"mm{i}") if i % 2 == 0
               else make_cumsum(4096, 128))
g = OpGraph(ops)

orch = Orchestrator(AnalyticProfiler())
h = orch.register(g)
plan0 = orch.plan(h)
print("event: GPU throttles 4.0x before op 6\n")

# the serving view: the request is active and 6 ops in when the
# monitoring condition arrives
orch.admit(h)
orch.advance(h, 6)
restitched = orch.on_condition(
    RuntimeCondition(slowdown={"GPU": 4.0}))[(h, "latency")]
print(f"static plan : {plan0.schedule.assignment}")
print(f"dynamic plan: {restitched.schedule.assignment}")
for e in orch.dynamic(h).events:
    print(f"remap at op {e.at_op} ({e.reason}): tail "
          f"{e.old_tail_cost*1e3:.2f} -> {e.new_tail_cost*1e3:.2f} ms predicted")
# the stitched plan carries real re-evaluated numbers (prefix at the
# nominal profile, tail under the throttled condition) — no NaNs
print(f"stitched plan: {restitched.latency*1e3:.2f} ms / "
      f"{restitched.energy*1e3:.2f} mJ predicted")
# cached nominal plans priced with GPU@1.0 were invalidated per-PU
print(f"plan cache after invalidation: {orch.stats}")

# -- realised latency: replay on the low-level DynamicScheduler ----------
event = {6: RuntimeCondition(slowdown={"GPU": 4.0})}
table = orch.workload(h).table
chain = g.topo_order()
dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
t_dyn = dyn.simulate(event)
static = DynamicScheduler(chain, g.ops, table, EDGE_PUS,
                          replan_threshold=1e9)
t_static = static.simulate(event)
print(f"\nrealised latency: static {t_static*1e3:.2f} ms, "
      f"dynamic {t_dyn*1e3:.2f} ms ({t_static/t_dyn:.2f}x)")
assert t_dyn < t_static
assert dyn.plan.assignment == restitched.schedule.assignment
