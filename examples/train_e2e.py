"""End-to-end training driver example: a ~100M-parameter dense LM trained
for a few hundred steps on the deterministic synthetic pipeline, with
atomic checkpoints, exact resume, and fault-managed stepping — the
complete production path of launch/train.py at example scale.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300]
(Defaults are sized for a CPU container; pass --d-model/--layers to grow.)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokenSource
from repro.fault.manager import FaultConfig, run_with_recovery
from repro.models import model as M
from repro.optim import adamw
from repro.sharding import Policy
from repro.launch.mesh import make_host_mesh
from repro.train import trainer as T

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--d-model", type=int, default=768)
ap.add_argument("--layers", type=int, default=12)
ap.add_argument("--vocab", type=int, default=4096)
ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
args = ap.parse_args()

# ~100M-parameter llama-family config (exact size printed below)
cfg = dataclasses.replace(
    get_config("llama3.2-1b"),
    name="llama-100m", n_layers=args.layers, d_model=args.d_model,
    n_heads=8, n_kv_heads=4, d_head=args.d_model // 8,
    d_ff=4 * args.d_model, vocab=args.vocab, dtype="float32", remat=False,
    q_chunk=64, kv_chunk=64)
print(f"config: {cfg.n_layers}L d={cfg.d_model} ff={cfg.d_ff} "
      f"vocab={cfg.vocab} -> {cfg.param_count()/1e6:.1f}M params")

mesh = make_host_mesh()
policy = Policy(mesh=mesh, fsdp=True)
source = SyntheticTokenSource(DataConfig(
    global_batch=args.batch, seq_len=args.seq, vocab=cfg.vocab))

tc = T.TrainConfig(opt=adamw.AdamWConfig(
    lr=1e-3, warmup_steps=30, total_steps=args.steps))
params = M.init_params(cfg, jax.random.PRNGKey(0))
opt_state = adamw.init_state(tc.opt, params)
step_fn = T.jit_train_step(cfg, tc, policy,
                           jax.eval_shape(lambda: params),
                           jax.eval_shape(lambda: source(0)))

state = {"params": params, "opt": opt_state}
start = 0
if ckpt.latest_step(args.ckpt_dir) is not None:
    state, extra = ckpt.restore(args.ckpt_dir, state)
    start = SyntheticTokenSource.resume_step(extra["data"])
    print(f"resuming from step {start}")

losses = []
t0 = time.time()


def one_step(i: int) -> None:
    batch = jax.tree.map(jnp.asarray, source(i))
    with mesh:
        p, o, met = step_fn(state["params"], state["opt"], batch)
    state["params"], state["opt"] = p, o
    losses.append(float(met["loss"]))
    if i % 20 == 0:
        dt = (time.time() - t0) / max(len(losses), 1)
        print(f"step {i:4d} loss {losses[-1]:7.4f} ({dt*1e3:.0f} ms/step)")


run_with_recovery(
    one_step, start_step=start, total_steps=args.steps,
    cfg=FaultConfig(checkpoint_every=100),
    save_fn=lambda i: ckpt.save(args.ckpt_dir, i, state,
                                extra={"data": source.checkpoint_state(i)}),
    restore_fn=lambda: start)

first = float(np.mean(losses[:10])) if len(losses) >= 10 else losses[0]
final = float(np.mean(losses[-10:]))
print(f"\ntrained {len(losses)} steps: loss {first:.3f} -> {final:.3f}")
assert final < first, "loss did not decrease"
print("loss decreased: OK")
