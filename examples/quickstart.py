"""Quickstart: BIDENT's register → plan → execute flow in ~60 lines.

The ``Orchestrator`` is the front door: hand it a cost provider once,
``register`` each inference graph (profiled + densified once, behind a
handle), ``plan`` whatever regime you need — the router picks the
sequential DP for chains, the phase/branch parallel solve when ``Branch``
nodes are present, the M-ary concurrent search for multiple handles — and
``execute`` the returned ``Plan`` on the multi-lane executor.  Repeated
``plan`` calls are served from the plan cache; the ``solve_*`` free
functions remain the low-level layer underneath.

1. Build a small model as a fused-operator graph (with real JAX payloads).
2. ``register`` it (profile on the edge-SoC cost model: CPU / GPU / NPU).
3. ``plan`` the three regimes: sequential, intra-model parallel,
   two concurrent requests.
4. ``execute`` the sequential plan and verify the outputs match
   monolithic execution exactly.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (AnalyticProfiler, FusedOp, OpGraph, Orchestrator,
                        ScheduleExecutor)

# -- 1. a tiny two-branch model: shared proj -> (conv path || scan path) --
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (1, 256, 256))
w1 = jax.random.normal(key, (256, 256)) * 0.05
w2 = jax.random.normal(key, (256, 128)) * 0.05

ops = [
    FusedOp(name="proj", kind="matmul", in_shapes=((1, 256, 256), (256, 256)),
            out_shape=(1, 256, 256), fn=lambda a: a @ w1),
    FusedOp(name="gemm_branch", kind="matmul",
            in_shapes=((1, 256, 256), (256, 128)), out_shape=(1, 256, 128),
            fn=lambda a: jax.nn.relu(a @ w2)),
    FusedOp(name="scan_branch", kind="cumsum", in_shapes=((1, 256, 256),),
            out_shape=(1, 256, 256), fn=lambda a: jnp.cumsum(a, axis=1)),
    FusedOp(name="join", kind="add", in_shapes=((1, 256, 128),) * 2,
            out_shape=(1, 256, 128),
            fn=lambda b, c: b + c[..., :128]),
]
graph = OpGraph(ops, edges=[(0, 1), (0, 2), (1, 3), (2, 3)])

# -- 2. register: profile -> (op, PU) cost table -> dense Workload, once --
orch = Orchestrator(AnalyticProfiler())
h = orch.register(graph)
table = orch.workload(h).table
print("supported PUs per op:",
      {op.name: table.supported_pus(i) for i, op in enumerate(graph.ops)})

# -- 3a. sequential shortest-path mapping ---------------------------------
seq = orch.plan(h, mode="sequential")
print("sequential:", [(graph.ops[o].name, p) for o, p in seq.route[0]],
      f"latency {seq.latency*1e6:.1f} us")

# -- 3b. intra-model parallel (auto-routed: the graph has Branch nodes) ---
par = orch.plan(h)
print(f"parallel: {par.latency*1e6:.1f} us "
      f"({par.schedule.n_concurrent_phases} concurrent phase(s))")

# -- 3c. two concurrent requests of this model ----------------------------
conc = orch.plan((h, h))
print(f"concurrent 2x: {conc.latency*1e6:.1f} us "
      f"(vs serial 2x sequential = {2*seq.latency*1e6:.1f} us)")
assert orch.plan((h, h)) is conc, "second identical plan() is a cache hit"

# -- 4. really run the plan; outputs must match monolithic ----------------
inputs = {0: (x,)}
orch_out = orch.execute(seq, inputs)
mono = orch.executor.run_monolithic(graph, inputs)
assert ScheduleExecutor.outputs_close(mono, orch_out), \
    "orchestration changed numerics!"
print("orchestrated output == monolithic output: OK")
