"""Quickstart: BIDENT end-to-end in ~60 lines.

1. Build a small model as a fused-operator graph (with real JAX payloads).
2. Profile it on the edge-SoC cost model (CPU / GPU / NPU).
3. Solve the three regimes: sequential, intra-model parallel, concurrent.
4. Execute the sequential schedule on the multi-lane orchestrator and
   verify the outputs match monolithic execution exactly.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (EDGE_PUS, AnalyticProfiler, ContentionModel,
                        FusedOp, OpGraph, ScheduleExecutor,
                        solve_concurrent_joint, solve_parallel,
                        solve_sequential)

# -- 1. a tiny two-branch model: shared proj -> (conv path || scan path) --
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (1, 256, 256))
w1 = jax.random.normal(key, (256, 256)) * 0.05
w2 = jax.random.normal(key, (256, 128)) * 0.05

ops = [
    FusedOp(name="proj", kind="matmul", in_shapes=((1, 256, 256), (256, 256)),
            out_shape=(1, 256, 256), fn=lambda a: a @ w1),
    FusedOp(name="gemm_branch", kind="matmul",
            in_shapes=((1, 256, 256), (256, 128)), out_shape=(1, 256, 128),
            fn=lambda a: jax.nn.relu(a @ w2)),
    FusedOp(name="scan_branch", kind="cumsum", in_shapes=((1, 256, 256),),
            out_shape=(1, 256, 256), fn=lambda a: jnp.cumsum(a, axis=1)),
    FusedOp(name="join", kind="add", in_shapes=((1, 256, 128),) * 2,
            out_shape=(1, 256, 128),
            fn=lambda b, c: b + c[..., :128]),
]
graph = OpGraph(ops, edges=[(0, 1), (0, 2), (1, 3), (2, 3)])

# -- 2. profile -> (op, PU) cost table ------------------------------------
table = AnalyticProfiler().profile(graph)
print("supported PUs per op:",
      {op.name: table.supported_pus(i) for i, op in enumerate(graph.ops)})

# -- 3a. sequential shortest-path mapping ---------------------------------
seq = solve_sequential(graph.topo_order(), graph.ops, table, EDGE_PUS)
print("sequential:", list(zip([graph.ops[i].name for i in seq.chain],
                              seq.assignment)),
      f"latency {seq.latency*1e6:.1f} us")

# -- 3b. intra-model parallel (branches co-execute) -----------------------
par = solve_parallel(graph, table, EDGE_PUS, ContentionModel())
print(f"parallel: {par.latency*1e6:.1f} us "
      f"({par.n_concurrent_phases} concurrent phase(s))")

# -- 3c. two concurrent requests of this model ----------------------------
conc = solve_concurrent_joint(graph.topo_order(), table,
                              graph.topo_order(), table, EDGE_PUS)
print(f"concurrent 2x: {conc.latency*1e6:.1f} us "
      f"(vs serial 2x sequential = {2*seq.latency*1e6:.1f} us)")

# -- 4. really run the schedule; outputs must match monolithic ------------
ex = ScheduleExecutor(list(EDGE_PUS))
inputs = {0: (x,)}
mono = ex.run_monolithic(graph, inputs)
orch = ex.run_scheduled(graph, dict(zip(seq.chain, seq.assignment)), inputs)
assert ScheduleExecutor.outputs_close(mono, orch), "orchestration changed numerics!"
print("orchestrated output == monolithic output: OK")
