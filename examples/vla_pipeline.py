"""The paper's multi-stage VLA pipeline as a DAG workload: vision
encoder || language encoder -> fusion -> action head, planned by the
antichain-frontier DAG route and executed end to end.

1. Build the compact VLA pipeline DAG (``paperzoo.vla_pipeline``): a
   conv tower (NPU-affine) forking from the inputs in parallel with a
   GEMM/attention tower (GPU-affine), joined by fusion + action head.
2. Plan it three ways: best *sequential* route (one PU-hopping sequence
   over a serialization of the DAG), the fork/join phase route, and the
   antichain-frontier route (``solve_dag(algorithm="frontier")``) that
   co-schedules the two encoders step by step on different PUs.
3. Execute the frontier plan on the multi-lane executor — lanes
   synchronize only at true dependency edges — and check the outputs
   bitwise against the single-lane reference run.

Run:  PYTHONPATH=src python examples/vla_pipeline.py
"""
import time

import numpy as np

from repro.core import (EDGE_PUS, EdgeSoCCostModel, Orchestrator,
                        results_bitwise_equal, solve_sequential)
from repro.core.paperzoo import vla_pipeline

# -- 1: the DAG ------------------------------------------------------------
graph = vla_pipeline()
n_vis = sum(op.name.startswith("vis.") for op in graph.ops)
n_lang = sum(op.name.startswith("lang.") for op in graph.ops)
print(f"VLA pipeline DAG: {len(graph.ops)} fused ops "
      f"({n_vis} vision, {n_lang} language, fusion + action head), "
      f"{len(graph.edges)} edges")

# attach small pure payloads so the plan actually executes: every op maps
# its predecessors' (8, 8) latents to a new latent (the analytic shapes
# above drive the cost model; payloads only need to be deterministic)
rng = np.random.default_rng(0)
for op in graph.ops:
    w = rng.standard_normal((8, 8)).astype(np.float32)

    def fn(*args, _w=w):
        x = sum(np.asarray(a, dtype=np.float32) for a in args)
        return np.tanh(x @ _w)

    op.fn = fn

# -- 2: plan ---------------------------------------------------------------
orch = Orchestrator(EdgeSoCCostModel(), pus=EDGE_PUS)
h = orch.register(graph)
table = orch._reg(h).table

# best sequential route: the chain DP over a serialization of the DAG —
# one op at a time on the best PU-hopping sequence (no co-execution)
seq = solve_sequential(graph.topo_order(), graph.ops, table, EDGE_PUS,
                       "latency")
phase = orch.plan(h, mode="dag", algorithm="phase")
frontier = orch.plan(h, mode="dag", algorithm="frontier")

print(f"\nbest sequential route : {seq.latency * 1e3:.4f} ms")
print(f"fork/join phase route : {phase.latency * 1e3:.4f} ms "
      f"({seq.latency / phase.latency:.2f}x vs sequential)")
print(f"antichain frontier    : {frontier.latency * 1e3:.4f} ms "
      f"({seq.latency / frontier.latency:.2f}x vs sequential, "
      f"{frontier.schedule.n_parallel_steps} co-scheduled steps)")
assert frontier.latency < seq.latency, \
    "intra-model parallelism must beat the best sequential route"

# -- 3: execute ------------------------------------------------------------
x = {0: (rng.standard_normal((8, 8)).astype(np.float32),)}
ref = orch.executor.run_monolithic(graph, x)

t0 = time.perf_counter()
out = orch.execute(frontier, x)                  # compiled lane program
t_first = time.perf_counter() - t0
t0 = time.perf_counter()
out = orch.execute(frontier, x)                  # warm: cached program
t_warm = time.perf_counter() - t0
ok = results_bitwise_equal(out, ref)
print(f"\nexecuted frontier plan on {len(EDGE_PUS)} lanes: "
      f"bitwise == single-lane reference: {ok} "
      f"(compile+run {t_first * 1e3:.1f} ms, warm run {t_warm * 1e3:.1f} ms)")
assert ok
print("VLA pipeline: planned and executed as a DAG workload: OK")
