"""Beyond-paper TPU mode: BIDENT's search over sharding strategies, and
the emitted overrides applied to a real lowered program.

1. Expand an assigned architecture into its fused-operator graph.
2. Run the BIDENT shortest-path search with sharding strategies as "PUs"
   (v5e roofline node costs, resharding-collective edge costs).
3. Emit Policy overrides from the schedule and lower a real train step
   under them, showing the sharding decisions land in the compiled HLO.

Run:  PYTHONPATH=src python examples/autoshard_tpu.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.autoshard import autoshard, emit_overrides
from repro.core.modelgraph import model_op_graph
from repro.models import model as M
from repro.sharding import Policy
from repro.launch.mesh import make_host_mesh

# -- 1+2: search ----------------------------------------------------------
arch = "granite-moe-1b-a400m"
cfg = get_config(arch)
g = model_op_graph(cfg, kind="train", batch=256, seq=4096)
res = autoshard(g, d_data=16, d_model=16)
print(res.summary())
res_direct = autoshard(g, d_data=16, d_model=16, direct_reshard=True)
print(f"with direct-reshard refinement: "
      f"{res_direct.schedule.latency*1e3:.2f} ms "
      f"({res_direct.speedup:.2f}x vs best single strategy)")

# -- 3: apply overrides to a real lowering --------------------------------
# map the schedule's dominant strategies onto the model's constrain sites
overrides = emit_overrides({
    "moe_xe": "EP" if "EP" in res.schedule.assignment else "DP_TP",
    "mlp_h": "DP_TP",
    "attn_q": "DP_TP",
})
print(f"\nemitted overrides: {overrides}")

rcfg = cfg.reduced()
mesh = make_host_mesh()
policy = Policy(mesh=mesh, fsdp=True, overrides=overrides)
params = jax.eval_shape(lambda: M.param_shapes(rcfg))
batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
with mesh:
    lowered = jax.jit(
        lambda p, b: M.loss_fn(rcfg, p, b, policy)[0]).lower(params, batch)
    compiled = lowered.compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):   # older jax returns one dict per device
    cost = cost[0] if cost else {}
print("lowered + compiled under BIDENT-emitted shardings: OK "
      f"({cost.get('flops', 0):.3g} HLO flops)")
