"""Streaming serving on the fig8 zoo: warm-start incremental re-planning
under live Poisson and bursty request traffic.

The :class:`ServingEngine` drives the orchestrator's online-admission API
(``admit`` / ``advance`` / ``retire``) as an asyncio serving loop:
requests arrive on a trace, are admitted into a bounded concurrent set,
and every membership or progress boundary is re-planned **warm** — the
pooled incremental solver re-prices only the affected region and sweeps
one bounded ``horizon_states`` window, so re-plan latency stays ~1 ms on
the full-resolution M=3 zoo set where a cold re-solve costs tens to
hundreds of ms (every warm plan is bitwise-identical to the cold solve;
``benchmarks/bench_serve.py`` gates that).  Deadline-tagged requests that
can no longer meet their SLO are shed gracefully instead of stalling the
set.

The last act switches to ``execution="real"``: requests really execute
through the fault runtime while a :class:`ChaosTrace` kills a PU mid-run
and brings it back — the per-target circuit breaker quarantines the
lane, the active set warm-re-plans on the survivors, and a half-open
probe re-admits the lane once it is observed healthy again.  Every
completed request is checked bitwise against a fault-free solo run.

Run:  PYTHONPATH=src python examples/streaming_serving.py
"""
import numpy as np

from repro.core import (ArrivalTrace, ChaosEvent, ChaosTrace,
                        EdgeSoCCostModel, ExecutionPolicy, FusedOp,
                        HealthPolicy, Orchestrator, ServingEngine,
                        chain_graph)
from repro.core.paperzoo import zoo

MODELS = ("ViT-B/16 FP16", "ResNet-50 FP16", "SNN-VGG9 FP16")

graphs = {name: zoo()[name] for name in MODELS}
orch = Orchestrator(EdgeSoCCostModel())
eng = ServingEngine(orch, graphs, max_concurrent=3)

# -- steady Poisson load --------------------------------------------------
trace = ArrivalTrace.poisson(list(MODELS), rate=4.0, n=20, seed=0)
rep = eng.serve(trace)
print(f"poisson  n={rep.n_requests:3d}: {rep.completed} served, "
      f"{rep.shed} shed, {rep.throughput:5.1f} req/s sustained")
print(f"         plan latency p50/p99 {rep.plan_ms_p50:.2f}/"
      f"{rep.plan_ms_p99:.2f} ms (wall)  "
      f"request latency p50/p99 {1e3 * rep.latency_p50:.1f}/"
      f"{1e3 * rep.latency_p99:.1f} ms")
print(f"         re-plans: {rep.replans_warm} warm, "
      f"{rep.replans_cold} cold")

# -- bursty overload with SLO deadlines -----------------------------------
# 3-request bursts land near-simultaneously; a tight SLO (2.5x each
# model's solo-best latency) forces the engine to shed what cannot make
# its deadline instead of letting the queue blow up
eng2 = ServingEngine(Orchestrator(EdgeSoCCostModel()), graphs,
                     max_concurrent=3, slo_factor=2.5)
burst = ArrivalTrace.bursty(list(MODELS), rate=60.0, n=20, burst_every=4,
                            burst_size=3, seed=1)
rep2 = eng2.serve(burst)
print(f"bursty   n={rep2.n_requests:3d}: {rep2.completed} served, "
      f"{rep2.shed} shed under SLO, {rep2.throughput:5.1f} req/s, "
      f"mean occupancy {rep2.occupancy_mean:.2f}/{eng2.max_concurrent}")
assert rep2.completed + rep2.shed == rep2.n_requests
assert rep.replans_cold == 0 and rep2.replans_cold == 0

# -- degraded-mode serving: real execution under chaos --------------------
# small jax-payload chains (the zoo graphs carry no executable payloads)
import jax.numpy as jnp


def _chain(n, salt):
    def payload(k):
        w = jnp.asarray(np.random.default_rng(salt * 97 + k)
                        .standard_normal((8, 8)).astype(np.float32))
        return lambda x, w=w: jnp.tanh(x @ w)
    g = chain_graph([FusedOp(name=f"c{salt}_{k}", kind="matmul",
                             flops=1e6, bytes_moved=1e4, fn=payload(k))
                     for k in range(n)])
    x = jnp.asarray(np.random.default_rng(salt)
                    .standard_normal((1, 8)).astype(np.float32))
    return g, {0: (x,)}


gA, inA = _chain(5, 1)
gB, inB = _chain(4, 2)
eng3 = ServingEngine(Orchestrator(EdgeSoCCostModel()), {"A": gA, "B": gB},
                     execution="real", inputs={"A": inA, "B": inB},
                     exec_policy=ExecutionPolicy(timeout=20.0),
                     health_policy=HealthPolicy(cooldown=0.005),
                     max_concurrent=2)
trace3 = ArrivalTrace.poisson(["A", "B"], rate=50.0, n=12, seed=3)
chaos = ChaosTrace([
    ChaosEvent(time=trace3.arrivals[4].time, kind="pu_lost", lane="CPU"),
    ChaosEvent(time=trace3.arrivals[8].time, kind="pu_restored",
               lane="CPU"),
], kind="pu_lost_return", seed=3)
rep3 = eng3.serve(trace3, chaos=chaos)
b = rep3.breaker
print(f"chaos    n={rep3.n_requests:3d}: {rep3.completed} served "
      f"({rep3.recovered} through a recovery), {rep3.shed} shed "
      f"{rep3.shed_reasons}, bitwise {rep3.bitwise_checked} checked / "
      f"{rep3.bitwise_failures} failed")
print(f"         breaker: {b['opens']} opens, {b['probes']} probes, "
      f"{b['readmits']} readmits; {rep3.recoveries} recoveries "
      f"(p50 {rep3.recovery_ms_p50:.2f} ms)")
for t in b["transitions"]:
    print(f"           t={t['time']:.3f}s {t['pu']}: "
          f"{t['frm']} -> {t['to']} ({t['reason']})")
assert rep3.bitwise_failures == 0
assert b["opens"] >= 1 and b["readmits"] >= 1
