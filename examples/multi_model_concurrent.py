"""Multi-model concurrent inference: the paper's Fig. 7(b) on real models,
extended from pairs to M concurrent requests — register → plan → execute.

Three models register with one ``Orchestrator`` session; ``plan`` over
the handle tuple routes to the M-request joint search (exact grid A*
here; pairs keep the 2-D A*), and ``execute`` REALLY RUNS the schedule
across the multi-lane executor (one worker lane per PU, all models
multiplexed onto the shared lanes) — through the **compiled lane
program** by default (segment-fused, cached; co-scheduled steps stay
single-op barrier segments), with the per-op interpreter retained as
the bitwise oracle via ``compile=False`` — verifying each model's
outputs against isolated execution.  The serving scenario is then played out
online: two requests are admitted, make progress, and a third arrives
mid-flight — ``admit`` re-plans the concurrent set over every active
request's *remaining* ops.

Run:  PYTHONPATH=src python examples/multi_model_concurrent.py
"""
import jax
import jax.numpy as jnp

from repro.core import (AnalyticProfiler, FusedOp, OpGraph, Orchestrator,
                        ScheduleExecutor)

key = jax.random.PRNGKey(0)


def gemm_model(name: str, n_layers: int, width: int):
    """A GEMM-heavy request (GPU-affine)."""
    ws = [jax.random.normal(jax.random.fold_in(key, i),
                            (width, width)) * (1.0 / width) ** 0.5
          for i in range(n_layers)]
    ops = [FusedOp(name=f"{name}.mm{i}", kind="matmul",
                   in_shapes=((1, width, width), (width, width)),
                   out_shape=(1, width, width),
                   fn=(lambda w: lambda a: jax.nn.relu(a @ w))(ws[i]))
           for i in range(n_layers)]
    return OpGraph(ops), jax.random.normal(key, (1, width, width))


def scan_model(name: str, n_layers: int, width: int):
    """A recurrence-heavy request (CPU-affine — Mamba/KAN class)."""
    ops = []
    for i in range(n_layers):
        ops.append(FusedOp(
            name=f"{name}.scan{i}", kind="cumsum",
            in_shapes=((1, width, width),), out_shape=(1, width, width),
            fn=lambda a: jnp.cumsum(a, axis=1) / a.shape[1]))
    return OpGraph(ops), jax.random.normal(key, (1, width, width))


def conv_model(name: str, n_layers: int, width: int):
    """A conv-heavy request (NPU-affine — ResNet/SNN class)."""
    ops = []
    for i in range(n_layers):
        w = jax.random.normal(jax.random.fold_in(key, 100 + i),
                              (width, width)) * (1.0 / width) ** 0.5
        ops.append(FusedOp(
            name=f"{name}.cv{i}", kind="conv2d",
            in_shapes=((1, 32, 24, 24), (32, 32, 3, 3)),
            out_shape=(1, 32, 24, 24),
            fn=(lambda wi: lambda a: jnp.tanh(a @ wi))(w)))
    return OpGraph(ops), jax.random.normal(key, (1, width, width))


models = [gemm_model("A", 8, 512), scan_model("B", 8, 512),
          conv_model("C", 6, 512)]
orch = Orchestrator(AnalyticProfiler())
handles = [orch.register(g) for g, _ in models]
serial = sum(orch.workload(h).best_solo()[1]   # best single PU, back to back
             for h in handles)

plan = orch.plan(handles)
print(f"serial best-single: {1e3*serial:.2f} ms")
print(f"BIDENT {len(models)}-model concurrent ({plan.schedule.mode}): "
      f"{1e3*plan.latency:.2f} ms -> {serial/plan.latency:.2f}x")

# show the first few co-scheduled steps (Fig. 7(b) style)
print("\nfirst 6 concurrent steps:")
for st in plan.schedule.steps[:6]:
    cols = []
    for r, (g, _) in enumerate(models):
        cols.append(f"{g.ops[st.ops[r]].name}@{st.pus[r]}"
                    if st.ops[r] is not None else "--idle--")
    print("  " + " || ".join(f"{c:16s}" for c in cols)
          + f" ({st.cost*1e6:7.1f} us)")

# really execute the M-model plan across the shared PU lanes — through
# the compiled path (the default): lane queues partition into segments,
# segment payloads fuse (jitted where bitwise-safe), and a repeat
# execute() hits the cached program.  Verify every model's outputs
# against isolated execution AND against the per-op interpreter oracle.
inputs = [{0: (x,)} for _, x in models]
conc = orch.execute(plan, inputs)                       # compiled
oracle = orch.execute(plan, inputs, compile=False)      # interpreter
graphs = [g for g, _ in models]
for g, x, got, ref in zip(graphs, inputs, conc, oracle):
    mono = orch.executor.run_monolithic(g, x)
    assert ScheduleExecutor.outputs_close(mono, got)
    assert ScheduleExecutor.outputs_close(ref, got)
prog = orch.program_for(plan, inputs)
s = prog.stats
orch.execute(plan, inputs)                              # program-cache hit
print(f"\nall {len(models)} models' compiled outputs == isolated == "
      f"interpreter oracle: OK")
print(f"compiled lane program: {s['n_segments']} segments over "
      f"{s['n_ops']} ops ({s['n_jitted']} jitted, {s['n_python']} python, "
      f"{s['n_barrier']} co-scheduled barriers); "
      f"program cache hits {orch.stats['program_hits']}")

# -- the serving scenario: a request arrives mid-flight -------------------
hA, hB, hC = handles
orch.admit(hA)
orch.admit(hB)
orch.advance(hA, 5)           # A is 5 ops in when C arrives
orch.advance(hB, 3)
online = orch.admit(hC)
rem = [len(r) for r in online.route]
print(f"\nonline admission: C arrives with A at op 5/8, B at op 3/8 -> "
      f"re-planned over remaining ops {rem} "
      f"({1e3*online.latency:.2f} ms, mode {online.schedule.mode})")
done = orch.retire(hA)
print(f"A retires -> re-planned set {done.handles}, "
      f"{1e3*done.latency:.2f} ms")
