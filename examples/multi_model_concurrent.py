"""Multi-model concurrent inference: the paper's Fig. 7(b) on real models.

Two models' operator graphs are co-scheduled with the joint (i, j)
Dijkstra; the schedule is then REALLY EXECUTED on the multi-lane
orchestrator (one worker lane per PU), and outputs are verified against
isolated execution.  Finally the predicted concurrent makespan is
compared with homogeneous serial execution.

Run:  PYTHONPATH=src python examples/multi_model_concurrent.py
"""
import jax
import jax.numpy as jnp

from repro.core import (EDGE_PUS, AnalyticProfiler, ContentionModel,
                        FusedOp, OpGraph, ScheduleExecutor,
                        solve_concurrent_joint)
from repro.core.schedule import single_pu_cost

key = jax.random.PRNGKey(0)


def gemm_model(name: str, n_layers: int, width: int):
    """A GEMM-heavy request (GPU-affine)."""
    ws = [jax.random.normal(jax.random.fold_in(key, i),
                            (width, width)) * (1.0 / width) ** 0.5
          for i in range(n_layers)]
    ops = [FusedOp(name=f"{name}.mm{i}", kind="matmul",
                   in_shapes=((1, width, width), (width, width)),
                   out_shape=(1, width, width),
                   fn=(lambda w: lambda a: jax.nn.relu(a @ w))(ws[i]))
           for i in range(n_layers)]
    return OpGraph(ops), jax.random.normal(key, (1, width, width))


def scan_model(name: str, n_layers: int, width: int):
    """A recurrence-heavy request (CPU-affine — Mamba/KAN class)."""
    ops = []
    for i in range(n_layers):
        ops.append(FusedOp(
            name=f"{name}.scan{i}", kind="cumsum",
            in_shapes=((1, width, width),), out_shape=(1, width, width),
            fn=lambda a: jnp.cumsum(a, axis=1) / a.shape[1]))
    return OpGraph(ops), jax.random.normal(key, (1, width, width))


g_a, x_a = gemm_model("A", 8, 512)
g_b, x_b = scan_model("B", 8, 512)
prof = AnalyticProfiler()
t_a, t_b = prof.profile(g_a), prof.profile(g_b)

# serial baseline: each model on its own best single PU, back to back
chain_a, chain_b = g_a.topo_order(), g_b.topo_order()
bl_a = min(v for v in (single_pu_cost(chain_a, p, g_a.ops, t_a, EDGE_PUS)
                       for p in EDGE_PUS) if v)[0]
bl_b = min(v for v in (single_pu_cost(chain_b, p, g_b.ops, t_b, EDGE_PUS)
                       for p in EDGE_PUS) if v)[0]

sched = solve_concurrent_joint(chain_a, t_a, chain_b, t_b, EDGE_PUS,
                               ContentionModel())
print(f"serial best-single: {1e3*(bl_a+bl_b):.2f} ms "
      f"(A {1e3*bl_a:.2f} + B {1e3*bl_b:.2f})")
print(f"BIDENT concurrent:  {1e3*sched.latency:.2f} ms "
      f"-> {(bl_a+bl_b)/sched.latency:.2f}x")

# show the first few co-scheduled steps (Fig. 7(b) style)
print("\nfirst 6 concurrent steps (opA@PU || opB@PU):")
for st in sched.steps[:6]:
    a = (f"{g_a.ops[st.ops[0]].name}@{st.pus[0]}" if st.ops[0] is not None
         else "--idle--")
    b = (f"{g_b.ops[st.ops[1]].name}@{st.pus[1]}" if st.ops[1] is not None
         else "--idle--")
    print(f"  {a:20s} || {b:20s} ({st.cost*1e6:7.1f} us)")

# really execute both schedules on the lane executor and verify outputs
ex = ScheduleExecutor(list(EDGE_PUS))
for g, x, req in ((g_a, x_a, 0), (g_b, x_b, 1)):
    assign = dict(sched.assignment_of(req))
    mono = ex.run_monolithic(g, {0: (x,)})
    orch = ex.run_scheduled(g, assign, {0: (x,)})
    assert ScheduleExecutor.outputs_close(mono, orch)
print("\nboth models' orchestrated outputs == monolithic: OK")
