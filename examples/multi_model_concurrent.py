"""Multi-model concurrent inference: the paper's Fig. 7(b) on real models,
extended from pairs to M concurrent requests.

Three models' operator graphs are co-scheduled with the M-request joint
search (``solve_concurrent`` — exact grid A* here; pairs keep the 2-D
A*); the schedule is then REALLY EXECUTED across the multi-lane
orchestrator (one worker lane per PU, all models multiplexed onto the
shared lanes), and each model's outputs are verified against isolated
execution.  Finally the predicted concurrent makespan is compared with
homogeneous serial execution.

Run:  PYTHONPATH=src python examples/multi_model_concurrent.py
"""
import jax
import jax.numpy as jnp

from repro.core import (EDGE_PUS, AnalyticProfiler, ContentionModel,
                        FusedOp, OpGraph, ScheduleExecutor, Workload,
                        solve_concurrent)

key = jax.random.PRNGKey(0)


def gemm_model(name: str, n_layers: int, width: int):
    """A GEMM-heavy request (GPU-affine)."""
    ws = [jax.random.normal(jax.random.fold_in(key, i),
                            (width, width)) * (1.0 / width) ** 0.5
          for i in range(n_layers)]
    ops = [FusedOp(name=f"{name}.mm{i}", kind="matmul",
                   in_shapes=((1, width, width), (width, width)),
                   out_shape=(1, width, width),
                   fn=(lambda w: lambda a: jax.nn.relu(a @ w))(ws[i]))
           for i in range(n_layers)]
    return OpGraph(ops), jax.random.normal(key, (1, width, width))


def scan_model(name: str, n_layers: int, width: int):
    """A recurrence-heavy request (CPU-affine — Mamba/KAN class)."""
    ops = []
    for i in range(n_layers):
        ops.append(FusedOp(
            name=f"{name}.scan{i}", kind="cumsum",
            in_shapes=((1, width, width),), out_shape=(1, width, width),
            fn=lambda a: jnp.cumsum(a, axis=1) / a.shape[1]))
    return OpGraph(ops), jax.random.normal(key, (1, width, width))


def conv_model(name: str, n_layers: int, width: int):
    """A conv-heavy request (NPU-affine — ResNet/SNN class)."""
    ops = []
    for i in range(n_layers):
        w = jax.random.normal(jax.random.fold_in(key, 100 + i),
                              (width, width)) * (1.0 / width) ** 0.5
        ops.append(FusedOp(
            name=f"{name}.cv{i}", kind="conv2d",
            in_shapes=((1, 32, 24, 24), (32, 32, 3, 3)),
            out_shape=(1, 32, 24, 24),
            fn=(lambda wi: lambda a: jnp.tanh(a @ wi))(w)))
    return OpGraph(ops), jax.random.normal(key, (1, width, width))


models = [gemm_model("A", 8, 512), scan_model("B", 8, 512),
          conv_model("C", 6, 512)]
prof = AnalyticProfiler()
workloads = []
serial = 0.0
for g, _ in models:
    table = prof.profile(g)
    wl = Workload.build(g.topo_order(), table, EDGE_PUS, ops=g.ops)
    workloads.append(wl)
    serial += wl.best_solo()[1]   # best single PU, back to back

sched = solve_concurrent(workloads, ContentionModel())
print(f"serial best-single: {1e3*serial:.2f} ms")
print(f"BIDENT {len(models)}-model concurrent ({sched.mode}): "
      f"{1e3*sched.latency:.2f} ms -> {serial/sched.latency:.2f}x")

# show the first few co-scheduled steps (Fig. 7(b) style)
print("\nfirst 6 concurrent steps:")
for st in sched.steps[:6]:
    cols = []
    for r, (g, _) in enumerate(models):
        cols.append(f"{g.ops[st.ops[r]].name}@{st.pus[r]}"
                    if st.ops[r] is not None else "--idle--")
    print("  " + " || ".join(f"{c:16s}" for c in cols)
          + f" ({st.cost*1e6:7.1f} us)")

# really execute the M-model schedule across the shared PU lanes and
# verify every model's outputs against isolated execution
ex = ScheduleExecutor(list(EDGE_PUS))
graphs = [g for g, _ in models]
inputs = [{0: (x,)} for _, x in models]
conc = ex.run_concurrent(graphs, sched, inputs)
for g, x, got in zip(graphs, inputs, conc):
    mono = ex.run_monolithic(g, x)
    assert ScheduleExecutor.outputs_close(mono, got)
print(f"\nall {len(models)} models' orchestrated outputs == isolated: OK")
