"""Heterogeneous serving: BIDENT's Fig. 5 on a real model, through the
register → plan → execute front door.

The ``Orchestrator`` session owns the cost provider and the plan cache —
the serving posture: ``register`` the decode-step operator graph once
(profiled + densified behind a handle), then ``plan`` it under latency
AND energy objectives (the second objective reuses the same memoized
``Workload``; a repeated ``plan`` call is a cache hit).  The per-operator
PU path (the paper's Fig. 5 "highlighted path") is read off
``plan.route``, and batched requests are then actually served with the
engine.

The second half swaps the analytic EdgeSoC cost model for **two real
registered targets** (``numpy-eager`` and ``xla-cpu`` from the builtin
registry): the same plan loop, but the per-op costs are measured on the
bound backends and the compiled lane program actually executes on them,
probe-verified against the reference composition.

Run:  PYTHONPATH=src python examples/heterogeneous_serving.py [--arch ...]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.core import EdgeSoCCostModel, MeasuredProfiler, Orchestrator
from repro.core.backends import default_registry
from repro.core.modelgraph import kernel_chain, model_op_graph
from repro.models import model as M
from repro.serving.engine import Engine
from repro.sharding import Policy

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="zamba2-2.7b", choices=ALL_ARCHS)
ap.add_argument("--batch", type=int, default=2)
args = ap.parse_args()

# -- register the decode-step operator graph ------------------------------
cfg_full = get_config(args.arch)
g = model_op_graph(cfg_full, kind="decode", batch=1, seq=2048)
orch = Orchestrator(EdgeSoCCostModel())
h = orch.register(g)

for objective in ("latency", "energy"):
    plan = orch.plan(h, objective=objective)
    counts: dict[str, int] = {}
    for _, pu in plan.route[0]:
        counts[pu] = counts.get(pu, 0) + 1
    print(f"{args.arch} decode, {objective}-optimal: "
          f"{plan.latency*1e3:.2f} ms / {plan.energy*1e3:.1f} mJ, "
          f"assignment {counts}")

# Fig. 5-style path for the first layer's operators (cache hit: the
# latency plan above is served back from the plan cache)
plan = orch.plan(h)
table = orch.workload(h).table
print("\nper-operator path (first 12 ops):")
for oi, pu in plan.route[0][:12]:
    op = g.ops[oi]
    best1 = min(table.supported_pus(oi),
                key=lambda p: table.require(oi, p).w)
    print(f"  {op.name:24s} kind={op.kind:9s} -> {pu}"
          + ("   (solo-best: %s)" % best1 if best1 != pu else ""))

_, base, _ = orch.workload(h).best_solo()
print(f"\nbest single PU {base*1e3:.2f} ms -> BIDENT {plan.latency*1e3:.2f} ms "
      f"({base/plan.latency:.2f}x)   [plan cache: {orch.stats}]")

# the compiled execution path: the plan's lane queues partition into
# maximal same-PU segments with handoff events only at the cross-lane
# cuts — the dispatch shape a real command-queue runtime would see
prog = orch.program_for(plan)
s = prog.stats
print(f"compiled lane program: {s['n_ops']} ops -> {s['n_segments']} "
      f"segments ({s['n_ops'] / max(s['n_segments'], 1):.1f} ops/segment; "
      f"{'serial' if s['serial'] else 'multi-lane'} dispatch)")

# -- the same loop on two REAL registered targets -------------------------
# The registry carries the builtin backends as data; binding a subset of
# them as PU lanes makes the orchestrator profile, plan, and execute on
# the actual backends instead of the analytic EdgeSoC model.
reg = default_registry()
binding = {name: reg.get(name) for name in ("numpy-eager", "xla-cpu")}
kg, kext = kernel_chain(blocks=1, seq=64, heads=2, head_dim=16,
                        state=8, moe_ff=16, chunk=32,
                        block_q=32, block_k=32)
ktable = MeasuredProfiler(warmup=1, iters=3, targets=binding).profile(kg)
korch = Orchestrator(ktable, targets=binding)
kplan = korch.plan(korch.register(kg))
kprog = korch.program_for(kplan)
kout = kprog.run(kext)
kref = korch.executor.run_monolithic(kg, kext)
route = [pu for _, pu in kplan.route[0]]
ks = kprog.stats
print(f"\nreal targets {list(binding)}: measured plan "
      f"{kplan.latency*1e6:.0f} us predicted, route "
      f"{dict((p, route.count(p)) for p in dict.fromkeys(route))}, "
      f"{ks['n_segments']} segments on bound backends "
      f"(verified: {ks['variant_verified'] or 'bitwise'}), outputs "
      f"{'match' if set(kout) == set(kref) else 'MISMATCH'} oracle")

# -- actually serve requests (reduced config on this CPU container) -------
cfg = cfg_full.reduced()
params = M.init_params(cfg, jax.random.PRNGKey(0))
engine = Engine(cfg=cfg, params=params, policy=Policy())
prompts = jnp.asarray(np.random.default_rng(0).integers(
    0, cfg.vocab, (args.batch, 16), dtype=np.int32))
out = engine.generate(prompts, max_new=8)
out = engine.generate(prompts, max_new=8)   # decode step: no re-trace
print(f"\nserved batch: prompts {prompts.shape} -> generated {out.shape} "
      f"(decode-step traces: {sum(engine.decode_trace_counts.values())} "
      f"across 2 generate calls)")
