"""Heterogeneous serving: BIDENT's Fig. 5 on a real model.

Builds the fused-operator graph of an assigned architecture's decode step,
runs the sequential shortest-path search under latency AND energy
objectives, prints the per-operator PU path (the paper's Fig. 5
"highlighted path"), then actually serves batched requests with the
engine.

Run:  PYTHONPATH=src python examples/heterogeneous_serving.py [--arch ...]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.core import EDGE_PUS, EdgeSoCCostModel, solve_sequential
from repro.core.schedule import single_pu_cost
from repro.core.modelgraph import model_op_graph
from repro.models import model as M
from repro.serving.engine import Engine
from repro.sharding import Policy

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="zamba2-2.7b", choices=ALL_ARCHS)
ap.add_argument("--batch", type=int, default=2)
args = ap.parse_args()

# -- BIDENT mapping of the decode-step operator graph ---------------------
cfg_full = get_config(args.arch)
g = model_op_graph(cfg_full, kind="decode", batch=1, seq=2048)
table = EdgeSoCCostModel().build_table(g)
chain = g.topo_order()

for objective in ("latency", "energy"):
    s = solve_sequential(chain, g.ops, table, EDGE_PUS, objective)
    counts: dict[str, int] = {}
    for a in s.assignment:
        counts[a] = counts.get(a, 0) + 1
    print(f"{args.arch} decode, {objective}-optimal: "
          f"{s.latency*1e3:.2f} ms / {s.energy*1e3:.1f} mJ, "
          f"assignment {counts}")

# Fig. 5-style path for the first layer's operators
s = solve_sequential(chain, g.ops, table, EDGE_PUS)
print("\nper-operator path (first 12 ops):")
for pos in range(min(12, len(chain))):
    oi = chain[pos]
    op = g.ops[oi]
    best1 = min(table.supported_pus(oi),
                key=lambda p: table.require(oi, p).w)
    print(f"  {op.name:24s} kind={op.kind:9s} -> {s.assignment[pos]}"
          + ("   (solo-best: %s)" % best1 if best1 != s.assignment[pos]
             else ""))

base = min(v for v in (single_pu_cost(chain, p, g.ops, table, EDGE_PUS)
                       for p in EDGE_PUS) if v)[0]
print(f"\nbest single PU {base*1e3:.2f} ms -> BIDENT {s.latency*1e3:.2f} ms "
      f"({base/s.latency:.2f}x)")

# -- actually serve requests (reduced config on this CPU container) -------
cfg = cfg_full.reduced()
params = M.init_params(cfg, jax.random.PRNGKey(0))
engine = Engine(cfg=cfg, params=params, policy=Policy())
prompts = jnp.asarray(np.random.default_rng(0).integers(
    0, cfg.vocab, (args.batch, 16), dtype=np.int32))
out = engine.generate(prompts, max_new=8)
print(f"\nserved batch: prompts {prompts.shape} -> generated {out.shape}")
